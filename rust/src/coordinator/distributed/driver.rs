//! Cluster driver: the coordinator side ([`Cluster`], an
//! [`ExactPassExec`] the outer loop dispatches exact passes through),
//! the worker side ([`serve_worker`], shared verbatim by the in-process
//! harness and the `cluster` binary), and the loopback entry points
//! ([`run_loopback`] / [`resume_loopback`]) that spawn worker threads
//! against a real `127.0.0.1` listener.
//!
//! One round: broadcast `Work {round, w, shard}` to every live worker
//! (workers compute concurrently), then collect replies in **ascending
//! worker id** — the deterministic fold order that keeps f64 penalty
//! accumulation and oracle-ledger deltas reproducible run to run. A
//! failed receive attempt (checksum mismatch, truncated frame, dropped
//! reply, stall, severed link) charges deterministic backoff to the
//! virtual clock and re-requests the round; workers answer resends of a
//! round they already solved from a cached reply, byte for byte, so
//! retries are pure retransmissions — no duplicate oracle calls, and
//! the oracle-call ledger stays bitwise equal to the single-process
//! run. A worker that exhausts its retry budget is declared dead: its
//! residue classes are reassigned to the lowest-id survivor (which
//! cold-builds arenas for the absorbed classes — its own stay warm),
//! and only blocks *no* survivor could produce come back as `None`,
//! flowing into the requeue-first/degraded-pass recovery of PR 9.

use std::collections::HashMap;
use std::io::{self, ErrorKind};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use super::protocol::{
    read_frame_raw, recv_msg, send_msg, verify_frame, write_frame, Msg, PROTOCOL_VERSION,
    TAG_HEARTBEAT,
};
use super::transport::{connect_with_retry, TransportFaultKind, TransportFaultPlan, TransportStats};
use super::DistConfig;
use crate::coordinator::faults::{call_with_faults, FaultConfig, FaultPlan, FaultStats};
use crate::coordinator::metrics::Series;
use crate::coordinator::mp_bcfw::{self, MpBcfwConfig, MpBcfwRun};
use crate::coordinator::parallel::{ExactPassExec, PassReport};
use crate::model::plane::Plane;
use crate::model::problem::StructuredProblem;
use crate::model::scratch::OracleScratch;
use crate::oracle::wrappers::CountingOracle;
use crate::runtime::engine::{NativeEngine, ScoringEngine};
use crate::utils::timer::Stopwatch;

/// Real-seconds deadline for the initial `accept_workers` handshake.
const ACCEPT_TIMEOUT_S: f64 = 30.0;

/// Poll interval for non-blocking accept loops.
const ACCEPT_POLL_MS: u64 = 2;

/// Why one receive attempt failed.
enum RecvFail {
    /// The stream is still framed and usable — resend the round.
    Soft(io::Error),
    /// The link is gone or desynced — reconnect before resending.
    Dead(io::Error),
}

/// A decoded `Planes` reply.
struct WorkerReply {
    planes: Vec<(u64, Option<Plane>)>,
    calls_total: u64,
    shard_secs: f64,
    fault_delta: FaultStats,
    penalty_secs: f64,
}

/// Coordinator side of the cluster: owns the listener, one framed link
/// per worker, the residue-class ownership map, and the transport fault
/// plan + stats. Implements [`ExactPassExec`], so
/// `mp_bcfw::run_with_exec` drives it exactly where the in-process
/// executor would run.
pub struct Cluster<'p> {
    problem: &'p CountingOracle,
    listener: TcpListener,
    cfg: DistConfig,
    plan: TransportFaultPlan,
    links: Vec<Option<TcpStream>>,
    alive: Vec<bool>,
    /// Residue class -> owning worker id (starts as the identity; a
    /// death remaps the dead worker's classes to the lowest survivor).
    owner: Vec<usize>,
    /// Per-worker cumulative oracle-call counts already folded into the
    /// coordinator ledger (multi-process mode only).
    folded_calls: Vec<u64>,
    /// Fold remote `calls_total` deltas into `problem`'s ledger. True
    /// for the multi-process binary (workers own their oracles); false
    /// in-process (workers share the coordinator's atomic ledger).
    fold_remote_calls: bool,
    /// Virtual-seconds penalty accrued by transport recovery this pass
    /// (backoff, stalls); drained into the run's `FaultPlan` per pass.
    penalty_secs: f64,
    pub stats: TransportStats,
}

impl<'p> Cluster<'p> {
    /// Bind the coordinator listener. `addr` is usually
    /// `127.0.0.1:0` (in-process harness) or `127.0.0.1:<port>` (the
    /// `cluster` binary).
    pub fn bind(
        problem: &'p CountingOracle,
        cfg: &DistConfig,
        addr: &str,
        fold_remote_calls: bool,
    ) -> io::Result<Cluster<'p>> {
        assert!(cfg.workers >= 1, "a cluster needs at least one worker");
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        Ok(Cluster {
            problem,
            listener,
            cfg: cfg.clone(),
            plan: TransportFaultPlan::from_config(&cfg.transport),
            links: (0..cfg.workers).map(|_| None).collect(),
            alive: vec![true; cfg.workers],
            owner: (0..cfg.workers).collect(),
            folded_calls: vec![0; cfg.workers],
            fold_remote_calls,
            penalty_secs: 0.0,
            stats: TransportStats::default(),
        })
    }

    /// The bound address workers should connect to.
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept the initial `Hello` from every worker and reply
    /// `Welcome {worker, n_workers}` (the worker's residue-class
    /// modulus). Workers may connect in any order.
    pub fn accept_workers(&mut self) -> io::Result<()> {
        let deadline = Instant::now() + Duration::from_secs_f64(ACCEPT_TIMEOUT_S);
        let mut connected = 0usize;
        while connected < self.cfg.workers {
            match self.accept_hello(deadline) {
                Some((worker, stream)) => {
                    if self.links[worker].is_none() {
                        connected += 1;
                    }
                    self.links[worker] = Some(stream);
                }
                None => {
                    return Err(io::Error::new(
                        ErrorKind::TimedOut,
                        format!(
                            "cluster: only {connected}/{} workers connected within \
                             {ACCEPT_TIMEOUT_S}s",
                            self.cfg.workers
                        ),
                    ));
                }
            }
        }
        Ok(())
    }

    /// Accept one valid `Hello` (any worker id) before `deadline`,
    /// completing the handshake. Invalid or foreign connections are
    /// dropped and the wait continues.
    fn accept_hello(&mut self, deadline: Instant) -> Option<(usize, TcpStream)> {
        loop {
            match self.listener.accept() {
                Ok((mut stream, _)) => {
                    stream.set_nodelay(true).ok();
                    stream
                        .set_read_timeout(Some(Duration::from_secs_f64(
                            self.cfg.straggler_timeout_s.max(0.05),
                        )))
                        .ok();
                    if let Ok(Msg::Hello { worker, protocol }) = recv_msg(&mut stream) {
                        let k = worker as usize;
                        if protocol == PROTOCOL_VERSION && k < self.cfg.workers {
                            let welcome =
                                Msg::Welcome { worker, n_workers: self.cfg.workers as u64 };
                            if send_msg(&mut stream, &welcome).is_ok() {
                                return Some((k, stream));
                            }
                        }
                    }
                    // Bad handshake: drop the connection, keep waiting.
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return None;
                    }
                    std::thread::sleep(Duration::from_millis(ACCEPT_POLL_MS));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(ACCEPT_POLL_MS)),
            }
        }
    }

    /// Send `Shutdown` to every live worker (end of training).
    pub fn shutdown(&mut self) {
        for link in self.links.iter_mut().flatten() {
            let _ = send_msg(link, &Msg::Shutdown);
        }
    }

    fn lowest_alive(&self) -> Option<usize> {
        self.alive.iter().position(|&a| a)
    }

    /// Declare worker `k` permanently dead and remap its residue
    /// classes to the lowest-id survivor (None left if none remains).
    fn declare_dead(&mut self, k: usize) {
        if !self.alive[k] {
            return;
        }
        self.alive[k] = false;
        self.links[k] = None;
        self.stats.worker_deaths += 1;
        if let Some(s) = self.lowest_alive() {
            for o in self.owner.iter_mut() {
                if *o == k {
                    *o = s;
                }
            }
        }
    }

    fn send_work(&mut self, k: usize, round: u64, w: &[f64], blocks: &[u64]) -> io::Result<()> {
        let link = self.links[k].as_mut().ok_or_else(|| {
            io::Error::new(ErrorKind::NotConnected, format!("worker {k} has no link"))
        })?;
        let msg = Msg::Work { round, w: w.to_vec(), blocks: blocks.to_vec() };
        let out = send_msg(link, &msg);
        if out.is_err() {
            self.links[k] = None;
        }
        out
    }

    /// Wait (bounded) for worker `k` to reconnect after a severed link:
    /// accept connections until one presents `Hello {worker: k}`.
    fn await_reconnect(&mut self, k: usize) -> bool {
        let deadline = Instant::now() + Duration::from_secs_f64(self.cfg.straggler_timeout_s);
        while Instant::now() < deadline {
            if let Some((worker, stream)) = self.accept_hello(deadline) {
                if worker == k {
                    self.links[k] = Some(stream);
                    self.stats.reconnects += 1;
                    return true;
                }
                // A different worker reconnecting out of turn (e.g. a
                // stale backlog entry from one we declared dead): only
                // still-live workers get their link restored.
                if self.alive[worker] {
                    self.links[worker] = Some(stream);
                }
            }
        }
        false
    }

    /// Receive worker `k`'s reply for `round`, tolerating up to
    /// `heartbeat_limit` heartbeats, with the transport-fault plan
    /// applied between reading the raw frame and verifying it — the
    /// boundary where real corruption would land.
    fn recv_planes(&mut self, k: usize, round: u64, attempt: u64) -> Result<WorkerReply, RecvFail> {
        let decision = self.plan.decide(k as u64, round, attempt);
        let mut beats = 0u64;
        loop {
            let link = self.links[k].as_mut().ok_or_else(|| {
                RecvFail::Dead(io::Error::new(ErrorKind::NotConnected, "no link"))
            })?;
            link.set_read_timeout(Some(Duration::from_secs_f64(
                self.cfg.straggler_timeout_s.max(0.05),
            )))
            .ok();
            let (mut payload, hash) = match read_frame_raw(link) {
                Ok(f) => f,
                Err(e) => {
                    // Timeouts desync mid-frame and EOF means the peer
                    // is gone: either way the link must be rebuilt.
                    self.links[k] = None;
                    return Err(RecvFail::Dead(e));
                }
            };
            // Heartbeats pass through the fault boundary untouched (the
            // plan's decision applies to the round's actual reply).
            if payload.first() == Some(&TAG_HEARTBEAT) && verify_frame(&payload, hash).is_ok() {
                if let Ok(Msg::Heartbeat { .. }) = Msg::decode(&payload) {
                    beats += 1;
                    if beats > self.cfg.heartbeat_limit {
                        self.links[k] = None;
                        return Err(RecvFail::Dead(io::Error::new(
                            ErrorKind::TimedOut,
                            format!("worker {k}: {beats} heartbeats without a reply"),
                        )));
                    }
                    continue;
                }
            }
            let decoded = match decision {
                Some(TransportFaultKind::Drop) => {
                    self.stats.dropped += 1;
                    return Err(RecvFail::Soft(io::Error::new(
                        ErrorKind::Other,
                        "injected reply drop",
                    )));
                }
                Some(TransportFaultKind::Stall) => {
                    self.stats.stalled += 1;
                    self.penalty_secs += self.cfg.straggler_timeout_s;
                    return Err(RecvFail::Soft(io::Error::new(
                        ErrorKind::TimedOut,
                        "injected straggler stall",
                    )));
                }
                Some(TransportFaultKind::Disconnect) => {
                    self.stats.disconnects += 1;
                    self.links[k] = None;
                    return Err(RecvFail::Dead(io::Error::new(
                        ErrorKind::ConnectionReset,
                        "injected disconnect",
                    )));
                }
                Some(TransportFaultKind::Garble) => {
                    self.stats.garbled += 1;
                    let pos = self.plan.garble_pos(k as u64, round, attempt, payload.len());
                    payload[pos] ^= 0x01;
                    // The flip must be caught by the checksum — a
                    // garbled f64 byte would otherwise decode "fine".
                    verify_frame(&payload, hash).and_then(|()| Msg::decode(&payload))
                }
                Some(TransportFaultKind::Truncate) => {
                    self.stats.truncated += 1;
                    // Deliver only half the payload: the decoder must
                    // die with a byte-offset error, like a short read.
                    Msg::decode(&payload[..payload.len() / 2])
                }
                None => verify_frame(&payload, hash).and_then(|()| Msg::decode(&payload)),
            };
            return match decoded {
                Ok(Msg::Planes {
                    round: r,
                    worker,
                    planes,
                    calls_total,
                    shard_secs,
                    fault_delta,
                    penalty_secs,
                }) if r == round && worker == k as u64 => Ok(WorkerReply {
                    planes,
                    calls_total,
                    shard_secs,
                    fault_delta,
                    penalty_secs,
                }),
                Ok(other) => {
                    // Wrong round or message kind: the stream is
                    // confused beyond patching — resync via reconnect.
                    self.links[k] = None;
                    Err(RecvFail::Dead(io::Error::new(
                        ErrorKind::InvalidData,
                        format!("worker {k}: unexpected reply {other:?} for round {round}"),
                    )))
                }
                // Corrupt frame, but the framing itself held: resend.
                Err(e) => Err(RecvFail::Soft(e)),
            };
        }
    }

    /// Collect worker `k`'s reply for `round`, retrying (resend +
    /// reconnect as needed) within the per-(worker, round) budget. Each
    /// retry charges deterministic exponential backoff to the virtual
    /// clock. Returns `None` once the budget is exhausted — the caller
    /// declares the worker dead.
    fn collect_with_retries(
        &mut self,
        k: usize,
        round: u64,
        w: &[f64],
        blocks: &[u64],
    ) -> Option<WorkerReply> {
        for attempt in 0..=self.cfg.reconnect_retries {
            if attempt > 0 {
                self.stats.retries += 1;
                self.penalty_secs +=
                    self.cfg.backoff_base_s * (1u64 << attempt.min(10)) as f64;
                if self.links[k].is_none() && !self.await_reconnect(k) {
                    continue;
                }
                if self.send_work(k, round, w, blocks).is_err() {
                    continue;
                }
            } else if self.links[k].is_none() {
                // The broadcast send already failed; rebuild + resend.
                if !self.await_reconnect(k) || self.send_work(k, round, w, blocks).is_err() {
                    continue;
                }
            }
            match self.recv_planes(k, round, attempt) {
                Ok(reply) => return Some(reply),
                Err(RecvFail::Soft(_)) | Err(RecvFail::Dead(_)) => continue,
            }
        }
        None
    }

    /// Fold one reply into the pass state — called in deterministic
    /// (ascending worker id, then reassignment) order, which is what
    /// keeps the f64 penalty accumulation and call-ledger deltas
    /// reproducible.
    fn fold_reply(
        &mut self,
        k: usize,
        reply: WorkerReply,
        by_block: &mut HashMap<u64, Option<Plane>>,
        shard_secs: &mut [f64],
        faults: &FaultPlan,
    ) {
        shard_secs[k] += reply.shard_secs;
        if self.fold_remote_calls {
            let delta = reply.calls_total.saturating_sub(self.folded_calls[k]);
            self.problem.charge_calls(delta);
            self.folded_calls[k] = reply.calls_total;
        }
        faults.absorb(&reply.fault_delta, reply.penalty_secs);
        for (b, p) in reply.planes {
            by_block.insert(b, p);
        }
    }
}

impl ExactPassExec for Cluster<'_> {
    fn pass(
        &mut self,
        w: &[f64],
        order: &[usize],
        pass: u64,
        faults: &FaultPlan,
    ) -> (Vec<Option<Plane>>, PassReport) {
        let sw = Stopwatch::start();
        let n_workers = self.cfg.workers;
        // Shard by residue class through the ownership map (identity
        // until a death reassigns classes to a survivor).
        let mut batches: Vec<Vec<u64>> = vec![Vec::new(); n_workers];
        for &i in order {
            batches[self.owner[i % n_workers]].push(i as u64);
        }
        let max_shard_len = batches.iter().map(Vec::len).max().unwrap_or(0);
        let mut shard_secs = vec![0.0f64; n_workers];
        let mut by_block: HashMap<u64, Option<Plane>> = HashMap::new();

        // Phase 1 — broadcast, so live workers compute concurrently.
        let mut pending: Vec<usize> = Vec::new();
        for k in 0..n_workers {
            if batches[k].is_empty() || !self.alive[k] {
                continue;
            }
            let _ = self.send_work(k, pass, w, &batches[k]);
            pending.push(k);
        }

        // Phase 2 — collect in ascending worker id; exhausted budgets
        // orphan the batch for reassignment.
        let mut orphans: Vec<u64> = Vec::new();
        for k in pending {
            let batch = std::mem::take(&mut batches[k]);
            match self.collect_with_retries(k, pass, w, &batch) {
                Some(reply) => self.fold_reply(k, reply, &mut by_block, &mut shard_secs, faults),
                None => {
                    self.declare_dead(k);
                    orphans.extend(batch);
                }
            }
        }
        // Blocks whose owner was already dead at broadcast time (no
        // survivor existed then either) are orphans too.
        for k in 0..n_workers {
            orphans.extend(std::mem::take(&mut batches[k]));
        }

        // Phase 3 — reassign orphans to the lowest-id survivor; cascade
        // if the survivor dies as well. Terminates: each loop iteration
        // either succeeds or strictly shrinks the set of live workers.
        while !orphans.is_empty() {
            let Some(s) = self.lowest_alive() else { break };
            self.stats.reassigned_blocks += orphans.len() as u64;
            let batch = std::mem::take(&mut orphans);
            if self.send_work(s, pass, w, &batch).is_err()
                && (!self.await_reconnect(s) || self.send_work(s, pass, w, &batch).is_err())
            {
                self.declare_dead(s);
                orphans = batch;
                continue;
            }
            match self.collect_with_retries(s, pass, w, &batch) {
                Some(reply) => self.fold_reply(s, reply, &mut by_block, &mut shard_secs, faults),
                None => {
                    self.declare_dead(s);
                    orphans = batch;
                }
            }
        }

        // Deterministic backoff/stall penalties accrued this pass drain
        // into the run's fault plan, which the outer loop charges to
        // the virtual clock — the same sink the oracle faults use.
        if self.penalty_secs > 0.0 {
            faults.absorb(&FaultStats::default(), self.penalty_secs);
            self.penalty_secs = 0.0;
        }

        // Order-aligned merge input. A block present as `None` failed
        // worker-side (oracle retry budget); a block absent entirely
        // could not be produced by any worker — count it lost. Both
        // requeue through the driver's fault machinery.
        let planes: Vec<Option<Plane>> = order
            .iter()
            .map(|&i| match by_block.remove(&(i as u64)) {
                Some(p) => p,
                None => {
                    self.stats.lost_blocks += 1;
                    None
                }
            })
            .collect();
        let report = PassReport { shard_secs, wall_secs: sw.secs(), max_shard_len };
        (planes, report)
    }
}

// ---- worker side -------------------------------------------------------

/// Worker-process configuration, shared by the in-process harness and
/// the `cluster worker` binary.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// This worker's id in `0..n_workers`.
    pub worker: u64,
    /// Warm-start the oracle arenas for this worker's own residue class
    /// (absorbed foreign classes always start cold).
    pub oracle_reuse: bool,
    /// Worker-side oracle fault schedule. Must equal the coordinator's
    /// `--faults*` config: decisions are pure in `(seed, block, pass,
    /// attempt)`, so equal configs give every executor the identical
    /// schedule and the distributed trajectory stays bitwise equal to
    /// the single-process faulty one.
    pub faults: FaultConfig,
    /// Real seconds to keep retrying the initial connect (the worker
    /// may start before the coordinator binds).
    pub connect_wait_s: f64,
    /// Reconnect attempts after a severed link before giving up.
    pub reconnect_retries: u64,
    /// Real-seconds base of the worker's exponential reconnect backoff.
    pub backoff_base_s: f64,
    /// Read deadline while waiting for `Welcome` and `Work` frames.
    pub read_timeout_s: f64,
    /// Test knob: send this many `Heartbeat` frames before each reply
    /// (exercises the coordinator's bounded heartbeat tolerance).
    pub heartbeats_per_round: u64,
    /// Test knob: exit (simulating a worker death) after serving this
    /// many rounds.
    pub quit_after_rounds: Option<u64>,
}

impl WorkerConfig {
    /// Worker defaults consistent with a [`DistConfig`].
    pub fn for_dist(worker: u64, dist: &DistConfig, faults: &FaultConfig) -> WorkerConfig {
        WorkerConfig {
            worker,
            oracle_reuse: true,
            faults: faults.clone(),
            connect_wait_s: ACCEPT_TIMEOUT_S,
            reconnect_retries: dist.reconnect_retries,
            backoff_base_s: dist.backoff_base_s,
            // The coordinator can go quiet between rounds (approx
            // passes, eval, checkpointing); be patient but bounded, so
            // an orphaned worker still exits. A timeout that fires
            // between rounds is self-healing: the worker reconnects,
            // and the coordinator's next failed send picks the fresh
            // connection up out of the listener backlog.
            read_timeout_s: (dist.straggler_timeout_s * 4.0).max(2.0),
            heartbeats_per_round: 0,
            quit_after_rounds: None,
        }
    }
}

fn handshake(cfg: &WorkerConfig, addr: SocketAddr) -> io::Result<(TcpStream, usize)> {
    let mut stream = connect_with_retry(addr, cfg.connect_wait_s)?;
    stream.set_read_timeout(Some(Duration::from_secs_f64(cfg.read_timeout_s.max(0.05))))?;
    send_msg(&mut stream, &Msg::Hello { worker: cfg.worker, protocol: PROTOCOL_VERSION })?;
    match recv_msg(&mut stream)? {
        Msg::Welcome { worker, n_workers } if worker == cfg.worker => {
            Ok((stream, n_workers as usize))
        }
        other => Err(io::Error::new(
            ErrorKind::InvalidData,
            format!("worker {}: expected Welcome, got {other:?}", cfg.worker),
        )),
    }
}

/// Bounded reconnect with deterministic exponential backoff (the real
/// sleep mirrors the virtual backoff the coordinator charges).
fn reconnect(cfg: &WorkerConfig, addr: SocketAddr) -> io::Result<(TcpStream, usize)> {
    let mut last = io::Error::new(ErrorKind::NotConnected, "no reconnect attempt made");
    for attempt in 0..=cfg.reconnect_retries {
        std::thread::sleep(Duration::from_secs_f64(
            cfg.backoff_base_s * (1u64 << attempt.min(10)) as f64,
        ));
        match handshake(cfg, addr) {
            Ok(out) => return Ok(out),
            Err(e) => last = e,
        }
    }
    Err(last)
}

/// Serve one worker: handshake, then answer `Work` rounds until
/// `Shutdown`. Owns one scratch arena per residue class it computes
/// for — its own class warm-started per `oracle_reuse`, absorbed
/// foreign classes (after another worker's death) built cold, mirroring
/// `exact_pass_faulty`'s dead-arena rebuild. Resends of an
/// already-solved round are answered from a cached encoded reply, byte
/// for byte, so coordinator-side retries never duplicate oracle calls.
pub fn serve_worker(
    problem: &CountingOracle,
    cfg: &WorkerConfig,
    addr: SocketAddr,
) -> io::Result<()> {
    let plan = FaultPlan::from_config(&cfg.faults);
    let (mut stream, n_workers) = handshake(cfg, addr)?;
    let mut arenas: Vec<Option<OracleScratch>> = (0..n_workers).map(|_| None).collect();
    let mut last_reported = FaultStats::default();
    // (round, blocks, encoded reply): answers resends without recompute.
    let mut cache: Option<(u64, Vec<u64>, Vec<u8>)> = None;
    let mut rounds_served = 0u64;
    loop {
        let msg = match recv_msg(&mut stream) {
            Ok(m) => m,
            Err(_) => {
                let (s, _) = reconnect(cfg, addr)?;
                stream = s;
                continue;
            }
        };
        match msg {
            Msg::Work { round, w, blocks } => {
                let payload = match &cache {
                    Some((r, b, payload)) if *r == round && *b == blocks => payload.clone(),
                    _ => {
                        let sw = Stopwatch::start();
                        let mut eng = NativeEngine;
                        let mut planes: Vec<(u64, Option<Plane>)> =
                            Vec::with_capacity(blocks.len());
                        for &b in &blocks {
                            let i = b as usize;
                            let k = i % n_workers;
                            let arena = arenas[k].get_or_insert_with(|| {
                                if k == cfg.worker as usize {
                                    OracleScratch::new(cfg.oracle_reuse)
                                } else {
                                    // Absorbed residue class: cold, like
                                    // the dead arena it replaces.
                                    OracleScratch::cold()
                                }
                            });
                            let plane = if plan.is_inject() {
                                call_with_faults(&plan, problem, i, &w, &mut eng, arena, round)
                                    .ok()
                            } else {
                                Some(problem.oracle_scratch(i, &w, &mut eng, arena))
                            };
                            planes.push((b, plane));
                        }
                        let now = plan.stats();
                        let delta = now.since(&last_reported);
                        last_reported = now;
                        let reply = Msg::Planes {
                            round,
                            worker: cfg.worker,
                            planes,
                            calls_total: problem.stats().calls,
                            shard_secs: sw.secs(),
                            fault_delta: delta,
                            penalty_secs: plan.take_penalty_secs(),
                        };
                        let payload = reply.encode();
                        cache = Some((round, blocks, payload.clone()));
                        payload
                    }
                };
                for _ in 0..cfg.heartbeats_per_round {
                    if send_msg(&mut stream, &Msg::Heartbeat { round }).is_err() {
                        break;
                    }
                }
                if write_frame(&mut stream, &payload).is_err() {
                    // The coordinator will resend the round; the cache
                    // answers it after the reconnect.
                    let (s, _) = reconnect(cfg, addr)?;
                    stream = s;
                    continue;
                }
                rounds_served += 1;
                if cfg.quit_after_rounds == Some(rounds_served) {
                    // Simulated worker death (test knob): vanish without
                    // a goodbye, exactly like a killed process.
                    return Ok(());
                }
            }
            Msg::Shutdown => return Ok(()),
            // Anything else mid-stream is a protocol hiccup; ignore.
            _ => {}
        }
    }
}

// ---- loopback entry points ---------------------------------------------

/// Run a full training session as 1 coordinator + `dist.workers`
/// in-process worker threads over real loopback TCP, returning the
/// series (with the `dist` columns filled) and the final run state.
/// The workers share `problem`'s atomic oracle ledger, so the
/// oracle-call counts are the single-process ones.
pub fn run_loopback(
    problem: &CountingOracle,
    eng: &mut dyn ScoringEngine,
    cfg: &MpBcfwConfig,
    dist: &DistConfig,
) -> io::Result<(Series, MpBcfwRun)> {
    run_loopback_with_quits(problem, eng, cfg, dist, &[])
}

/// [`run_loopback`] with per-worker `quit_after_rounds` knobs (tests
/// stage worker deaths with it; an empty slice means nobody quits).
pub fn run_loopback_with_quits(
    problem: &CountingOracle,
    eng: &mut dyn ScoringEngine,
    cfg: &MpBcfwConfig,
    dist: &DistConfig,
    quits: &[Option<u64>],
) -> io::Result<(Series, MpBcfwRun)> {
    let ((mut series, run), stats) =
        with_cluster(problem, dist, cfg, quits, |cluster, problem| {
            mp_bcfw::run_with_exec(problem, eng, cfg, cluster)
        })?;
    fill_dist_columns(&mut series, dist, &stats);
    Ok((series, run))
}

/// Resume a checkpointed run on a fresh loopback cluster (the
/// distributed analogue of `mp_bcfw::resume`): the trajectory continues
/// bitwise from the checkpoint, workers rebuild their arenas cold —
/// value-neutral, like any resume.
pub fn resume_loopback(
    problem: &CountingOracle,
    eng: &mut dyn ScoringEngine,
    cfg: &MpBcfwConfig,
    dist: &DistConfig,
    run: &mut MpBcfwRun,
) -> io::Result<Series> {
    let (mut series, stats) = with_cluster(problem, dist, cfg, &[], |cluster, problem| {
        mp_bcfw::resume_with_exec(problem, eng, cfg, run, cluster)
    })?;
    fill_dist_columns(&mut series, dist, &stats);
    Ok(series)
}

/// Stamp the distributed-run columns onto a finished series (shared by
/// the in-process loopback harness and the `cluster` binary).
pub fn fill_dist_columns(series: &mut Series, dist: &DistConfig, stats: &TransportStats) {
    series.dist = "loopback".to_string();
    series.dist_workers = dist.workers as u64;
    series.transport_faults = dist.transport.mode.name().to_string();
    series.transport_retries = stats.retries;
    series.worker_deaths = stats.worker_deaths;
    series.reassigned_blocks = stats.reassigned_blocks;
}

/// Spawn `dist.workers` serve threads against a fresh 127.0.0.1
/// listener, accept them, run `body` with the connected [`Cluster`],
/// then shut the workers down. Worker threads that error out (severed
/// links at run end, staged deaths) are joined and ignored — the
/// coordinator's own recovery already accounted for them.
fn with_cluster<R>(
    problem: &CountingOracle,
    dist: &DistConfig,
    cfg: &MpBcfwConfig,
    quits: &[Option<u64>],
    body: impl FnOnce(&mut Cluster, &CountingOracle) -> R,
) -> io::Result<(R, TransportStats)> {
    let mut cluster = Cluster::bind(problem, dist, "127.0.0.1:0", false)?;
    let addr = cluster.local_addr()?;
    let out = std::thread::scope(|s| -> io::Result<R> {
        let handles: Vec<_> = (0..dist.workers)
            .map(|k| {
                let mut wcfg = WorkerConfig::for_dist(k as u64, dist, &cfg.faults);
                wcfg.oracle_reuse = cfg.oracle_reuse;
                wcfg.quit_after_rounds = quits.get(k).copied().flatten();
                s.spawn(move || serve_worker(problem, &wcfg, addr))
            })
            .collect();
        cluster.accept_workers()?;
        let r = body(&mut cluster, problem);
        cluster.shutdown();
        for h in handles {
            // A worker that died (staged or declared) returns Err or
            // already exited; the cluster's stats carry the story.
            let _ = h.join();
        }
        Ok(r)
    })?;
    Ok((out, cluster.stats))
}

//! Fault-tolerant multi-process dBCFW: coordinator/worker training over
//! a crash-safe loopback transport.
//!
//! The paper's premise — the exact max-oracle dominates training time —
//! makes the exact pass the part worth distributing across processes
//! (the dBCFW shape of Lee et al., 2015). This module keeps the
//! single-machine trajectory contract while adding the new failure
//! domain that comes with processes and sockets:
//!
//!  * **Sharding** re-uses the established id-mod-N pinning: worker `k`
//!    of an `N`-worker cluster owns the residue class `block % N` —
//!    data access, working-set growth and `OracleScratch` arenas stay
//!    disjoint per worker, exactly like `parallel::exact_pass_with`'s
//!    per-thread arenas.
//!  * **Rounds** are bulk-synchronous: the coordinator broadcasts one
//!    epoch-stamped snapshot of w per outer pass (`protocol::Msg::Work`),
//!    workers solve their shards against it, and the coordinator merges
//!    the returned planes *sequentially in the sampled block order* —
//!    minibatch-BCFW semantics, so a same-seed 1-coordinator+N-worker
//!    run is **bitwise identical** to the single-process trajectory
//!    (the anchor test in `tests/distributed.rs`).
//!  * **Robustness** is the headline: length-prefixed checksummed
//!    frames with the checkpoint codec's OOM guards and byte-offset
//!    corruption errors (`protocol`), heartbeats with deadlines,
//!    bounded reconnect with deterministic backoff, worker-death
//!    detection with shard reassignment to the lowest-id survivor
//!    (cold-arena rebuild for the absorbed residue class, mirroring
//!    `exact_pass_faulty` — survivors stay warm), straggler timeouts
//!    folding into the PR-9 requeue-first/degraded-pass machinery, and
//!    coordinator-side auto-checkpointing via `save_run_atomic` so
//!    killing the whole cluster mid-round and resuming reproduces the
//!    uninterrupted eval tail bit for bit.
//!  * **Replayable failures**: transport faults are injected through a
//!    seeded plan pure in `(seed, worker, round, attempt)`
//!    (`transport::TransportFaultPlan`), so every failure scenario runs
//!    deterministically in-process without real sockets flaking, and
//!    `--transport-faults off` draws zero RNG — golden fixtures and the
//!    `bench --regress` gate never see the transport layer.
//!
//! Why recovery preserves the trajectory: a plane is a pure function of
//! `(block, snapshot-w)`, so *which* worker computes it — first owner,
//! reconnected owner, or the survivor a dead worker's shard was
//! reassigned to — cannot change its bits. As long as every block's
//! plane lands within the round, the merged trajectory is the
//! single-process one. Only a block that no surviving worker could
//! produce becomes `None`, flows into the requeue/degrade machinery,
//! and legitimately forks the trajectory (with the dual still
//! monotone — a lost block is just a block the sampler didn't visit).

pub mod driver;
pub mod protocol;
pub mod transport;

pub use driver::{
    fill_dist_columns, resume_loopback, run_loopback, run_loopback_with_quits, serve_worker,
    Cluster, WorkerConfig,
};
pub use transport::{TransportFaultConfig, TransportFaultPlan, TransportStats};

/// Where the exact pass runs (`--dist {single,loopback}`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DistMode {
    /// In-process execution (threads / sequential) — the default; the
    /// distributed layer is never constructed.
    #[default]
    Single,
    /// 1 coordinator + N workers over loopback TCP.
    Loopback,
}

impl DistMode {
    /// Parse a CLI token.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "single" => Some(DistMode::Single),
            "loopback" => Some(DistMode::Loopback),
            _ => None,
        }
    }

    /// Stable name for tables/JSON.
    pub fn name(&self) -> &'static str {
        match self {
            DistMode::Single => "single",
            DistMode::Loopback => "loopback",
        }
    }
}

/// Cluster shape + robustness knobs (CLI `--dist`, `--dist-workers`,
/// `--transport-faults`, `--transport-fault-seed`,
/// `--transport-fault-rate`, `--straggler-timeout`,
/// `--reconnect-retries`).
#[derive(Clone, Debug, PartialEq)]
pub struct DistConfig {
    /// `--dist {single,loopback}`.
    pub mode: DistMode,
    /// `--dist-workers N` — worker count (and the residue-class modulus
    /// for shard/arena pinning; a per-run constant even after deaths).
    pub workers: usize,
    /// Seeded transport-fault schedule (`--transport-faults*`).
    pub transport: TransportFaultConfig,
    /// `--straggler-timeout` — real seconds the coordinator waits on a
    /// worker's reply (heartbeats reset it) before failing the attempt.
    pub straggler_timeout_s: f64,
    /// `--reconnect-retries` — receive attempts beyond the first per
    /// (worker, round); exhausting them declares the worker dead and
    /// reassigns its shard.
    pub reconnect_retries: u64,
    /// Base of the deterministic exponential retry backoff, charged to
    /// the virtual clock (attempt `k` charges `base · 2^k`) and used as
    /// the worker's real reconnect sleep. Not CLI-exposed.
    pub backoff_base_s: f64,
    /// Max heartbeat frames tolerated while waiting for one reply.
    pub heartbeat_limit: u64,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            mode: DistMode::Single,
            workers: 2,
            transport: TransportFaultConfig::default(),
            straggler_timeout_s: 5.0,
            reconnect_retries: 2,
            backoff_base_s: 0.01,
            heartbeat_limit: 64,
        }
    }
}

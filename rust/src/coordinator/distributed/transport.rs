//! Loopback TCP transport with deterministic, replayable fault
//! injection.
//!
//! The injection design repeats `coordinator::faults`: a stateless
//! [`TransportFaultPlan`] whose every decision is a pure function of
//! `(seed, worker, round, attempt)`, derived through a throwaway
//! [`Pcg`] on a splitmix-mixed stream. Replaying a run replays the
//! exact fault schedule; resuming from a checkpoint replays the
//! schedule's tail (decisions are keyed by the absolute outer-pass
//! number, not by elapsed wall time); and `mode = Off` draws **zero**
//! RNG, so a faults-off cluster is structurally identical to a plain
//! run — golden fixtures and the `bench --regress` gate never see it.
//!
//! Faults are injected on the **coordinator side of the framing
//! boundary**, between reading a worker's raw reply frame and verifying
//! it. That placement is what makes every scenario exercisable without
//! real sockets flaking: a [`Garble`](TransportFaultKind::Garble) flips
//! one payload byte and must be caught by the frame checksum; a
//! [`Truncate`](TransportFaultKind::Truncate) decodes a half-received
//! payload and must die with a byte-offset error from the
//! `FrameReader`; a [`Drop`](TransportFaultKind::Drop) discards the
//! reply; a [`Stall`](TransportFaultKind::Stall) charges the straggler
//! timeout to the virtual clock and gives up on the attempt; a
//! [`Disconnect`](TransportFaultKind::Disconnect) closes the socket so
//! the worker's bounded reconnect path runs for real. All five funnel
//! into the same bounded-retry recovery in `driver::Cluster`.

use std::io;
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use crate::coordinator::faults::FaultMode;
use crate::utils::rng::Pcg;

/// Default per-attempt transport fault probability under `Inject`.
pub const DEFAULT_TRANSPORT_FAULT_RATE: f64 = 0.2;

/// What the plan can do to one coordinator-side receive attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportFaultKind {
    /// Flip one payload byte → frame checksum mismatch.
    Garble,
    /// Deliver only half the payload → byte-offset decode error.
    Truncate,
    /// Discard the reply frame entirely.
    Drop,
    /// Worker "hangs": charge the straggler timeout, fail the attempt.
    Stall,
    /// Sever the connection; the worker must reconnect with backoff.
    Disconnect,
}

impl TransportFaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            TransportFaultKind::Garble => "garble",
            TransportFaultKind::Truncate => "truncate",
            TransportFaultKind::Drop => "drop",
            TransportFaultKind::Stall => "stall",
            TransportFaultKind::Disconnect => "disconnect",
        }
    }
}

/// Transport-fault configuration (the `--transport-faults*` knobs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransportFaultConfig {
    pub mode: FaultMode,
    pub seed: u64,
    /// Per-receive-attempt injection probability in [0, 1].
    pub rate: f64,
    /// Restrict injection to outer passes in `[lo, hi]` (inclusive);
    /// `None` = every pass. Bench/test knob for staging scenarios.
    pub window: Option<(u64, u64)>,
}

impl Default for TransportFaultConfig {
    fn default() -> Self {
        TransportFaultConfig {
            mode: FaultMode::Off,
            seed: 0,
            rate: DEFAULT_TRANSPORT_FAULT_RATE,
            window: None,
        }
    }
}

/// The seeded schedule. Pure: `decide(worker, round, attempt)` always
/// returns the same answer for the same plan, independent of call
/// order, thread interleaving, or how many times it is asked — the
/// same throwaway-Pcg idiom as `FaultPlan::decide`, with an extra
/// domain-separation constant so a transport plan and an oracle
/// `FaultPlan` sharing a seed still draw uncorrelated schedules.
#[derive(Clone, Copy, Debug)]
pub struct TransportFaultPlan {
    mode: FaultMode,
    seed: u64,
    rate: f64,
    window: Option<(u64, u64)>,
}

impl TransportFaultPlan {
    pub fn from_config(cfg: &TransportFaultConfig) -> TransportFaultPlan {
        TransportFaultPlan { mode: cfg.mode, seed: cfg.seed, rate: cfg.rate, window: cfg.window }
    }

    pub fn off() -> TransportFaultPlan {
        TransportFaultPlan::from_config(&TransportFaultConfig::default())
    }

    pub fn is_inject(&self) -> bool {
        self.mode == FaultMode::Inject
    }

    fn active(&self, round: u64) -> bool {
        match self.window {
            None => true,
            Some((lo, hi)) => round >= lo && round <= hi,
        }
    }

    fn stream(&self, worker: u64, round: u64, attempt: u64) -> u64 {
        worker
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ round.wrapping_mul(0xBF58_476D_1CE4_E5B9)
            ^ attempt.wrapping_mul(0x94D0_49BB_1331_11EB)
            ^ 0xA076_1D64_78BD_642F
    }

    /// Should this receive attempt be sabotaged, and how? `Off` mode
    /// returns `None` without constructing an RNG.
    pub fn decide(&self, worker: u64, round: u64, attempt: u64) -> Option<TransportFaultKind> {
        if !self.is_inject() || !self.active(round) {
            return None;
        }
        let mut rng = Pcg::new(self.seed, self.stream(worker, round, attempt));
        if rng.f64() >= self.rate {
            return None;
        }
        Some(match rng.below(5) {
            0 => TransportFaultKind::Garble,
            1 => TransportFaultKind::Truncate,
            2 => TransportFaultKind::Drop,
            3 => TransportFaultKind::Stall,
            _ => TransportFaultKind::Disconnect,
        })
    }

    /// Deterministic byte position for a [`Garble`] of a `len`-byte
    /// payload (its own stream so it never perturbs `decide`).
    pub fn garble_pos(&self, worker: u64, round: u64, attempt: u64, len: usize) -> usize {
        debug_assert!(len > 0);
        let mut rng =
            Pcg::new(self.seed, self.stream(worker, round, attempt) ^ 0xD6E8_FEB8_6659_FD93);
        rng.below(len)
    }
}

/// Transport-layer event counters, accrued by the coordinator's driver
/// and surfaced in `Series` / the `dist` bench table.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportStats {
    pub garbled: u64,
    pub truncated: u64,
    pub dropped: u64,
    pub stalled: u64,
    pub disconnects: u64,
    /// Receive attempts beyond the first, per (worker, round).
    pub retries: u64,
    /// Workers declared permanently dead (retry budget exhausted).
    pub worker_deaths: u64,
    /// Reconnections accepted after a severed link.
    pub reconnects: u64,
    /// Blocks re-dispatched to a survivor after a worker death.
    pub reassigned_blocks: u64,
    /// Blocks returned as `None` because no worker could produce them;
    /// these requeue through the degraded-pass machinery and are the
    /// only transport outcome that forks the trajectory.
    pub lost_blocks: u64,
}

/// Connect to `addr`, retrying on `ConnectionRefused` until the
/// deadline — workers race the coordinator's `bind` at cluster start.
pub fn connect_with_retry(addr: SocketAddr, total_wait_s: f64) -> io::Result<TcpStream> {
    let poll = Duration::from_millis(25);
    let mut waited = Duration::ZERO;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) => {
                if waited.as_secs_f64() >= total_wait_s {
                    return Err(io::Error::new(
                        e.kind(),
                        format!("connecting to coordinator at {addr}: {e}"),
                    ));
                }
                std::thread::sleep(poll);
                waited += poll;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inject_plan(rate: f64, window: Option<(u64, u64)>) -> TransportFaultPlan {
        TransportFaultPlan::from_config(&TransportFaultConfig {
            mode: FaultMode::Inject,
            seed: 42,
            rate,
            window,
        })
    }

    #[test]
    fn decisions_are_pure_and_key_sensitive() {
        let plan = inject_plan(0.7, None);
        for worker in 0..3u64 {
            for round in 1..6u64 {
                for attempt in 0..3u64 {
                    let a = plan.decide(worker, round, attempt);
                    let b = plan.decide(worker, round, attempt);
                    assert_eq!(a, b, "decision must be pure in (worker, round, attempt)");
                }
            }
        }
        // Keys matter: across a grid this large, at least one pair of
        // adjacent keys must disagree at rate 0.7.
        let grid: Vec<Option<TransportFaultKind>> = (0..3u64)
            .flat_map(|w| (1..6u64).map(move |r| plan.decide(w, r, 0)))
            .collect();
        assert!(grid.iter().any(|d| d.is_some()), "rate 0.7 must inject somewhere");
        assert!(grid.iter().any(|d| d.is_none()), "rate 0.7 must also skip somewhere");
    }

    #[test]
    fn off_mode_and_window_suppress_injection() {
        let off = TransportFaultPlan::off();
        for round in 0..50u64 {
            assert_eq!(off.decide(0, round, 0), None);
        }
        let windowed = inject_plan(1.0, Some((3, 4)));
        assert_eq!(windowed.decide(0, 2, 0), None, "before window");
        assert!(windowed.decide(0, 3, 0).is_some(), "inside window");
        assert!(windowed.decide(0, 4, 0).is_some(), "inside window");
        assert_eq!(windowed.decide(0, 5, 0), None, "after window");
    }

    #[test]
    fn all_five_kinds_are_reachable() {
        let plan = inject_plan(1.0, None);
        let mut seen = std::collections::HashSet::new();
        for worker in 0..4u64 {
            for round in 1..40u64 {
                if let Some(k) = plan.decide(worker, round, 0) {
                    seen.insert(k.name());
                }
            }
        }
        assert_eq!(seen.len(), 5, "expected all fault kinds in 160 draws, got {seen:?}");
    }

    #[test]
    fn garble_positions_are_deterministic_and_in_range() {
        let plan = inject_plan(1.0, None);
        for len in [1usize, 9, 1024] {
            let a = plan.garble_pos(1, 2, 0, len);
            assert_eq!(a, plan.garble_pos(1, 2, 0, len));
            assert!(a < len);
        }
    }
}

//! Wire protocol of the loopback cluster: length-prefixed, checksummed
//! frames with the same paranoia as the run-checkpoint codec.
//!
//! A frame is `[payload_len: u64 LE][fnv1a64(payload): u64 LE][payload]`.
//! The length prefix is validated against [`MAX_FRAME_BYTES`] *before*
//! any allocation — the exact `CountingReader::with_limit` discipline of
//! `coordinator::checkpoint`: a bit-flipped length can produce an error
//! but never an OOM. The checksum catches payload corruption that would
//! otherwise decode into silently wrong planes (a flipped mantissa byte
//! is still a valid `f64`); structural corruption — truncated frames,
//! inner element counts that outrun the payload — is caught by
//! [`FrameReader`], which tracks its byte position and names the offset
//! at which decoding broke, exactly like `load_run` does for checkpoint
//! files.
//!
//! Messages ([`Msg`]) are deliberately few: a `Hello`/`Welcome`
//! handshake that pins the protocol version and the worker's identity,
//! a `Work` broadcast carrying the epoch-stamped weight snapshot plus
//! the receiver's block shard, the `Planes` reply (order-aligned
//! `Option<Plane>` results, repr-preserving, plus the worker's oracle
//! ledger and fault-recovery counters for the coordinator to fold), a
//! worker-side `Heartbeat` so a long solve is distinguishable from a
//! dead process, and `Shutdown`. Plane payloads reuse the checkpoint's
//! repr byte (0 = dense, 1 = sparse) so sparse oracle output crosses
//! the wire without densification and round-trips bit for bit.

use std::io::{Error, ErrorKind, Read, Result, Write};

use crate::coordinator::faults::FaultStats;
use crate::model::plane::{Plane, PlaneVec};

/// Protocol version pinned by the `Hello`/`Welcome` handshake; bump on
/// any wire-format change so mismatched binaries fail loudly instead of
/// mis-decoding each other.
pub const PROTOCOL_VERSION: u64 = 1;

/// Hard ceiling on a single frame's payload. Generous for any realistic
/// snapshot (a dense w at paper scale is a few MB) while keeping a
/// corrupt 8-byte length prefix from requesting an exabyte allocation.
pub const MAX_FRAME_BYTES: u64 = 1 << 28;

/// FNV-1a 64-bit: tiny, dependency-free, and plenty to catch the
/// single-byte garbles and torn writes a transport produces (this is an
/// integrity check against *accidents*, not an authentication code).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Write one frame (length prefix + checksum + payload).
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    w.write_all(&(payload.len() as u64).to_le_bytes())?;
    w.write_all(&fnv1a64(payload).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame's raw payload and its transmitted checksum, without
/// verifying the checksum (the coordinator's fault-injection boundary
/// sits between reading and verifying — see
/// `transport::TransportFaultPlan`). The length prefix is validated
/// against [`MAX_FRAME_BYTES`] before the payload buffer is allocated.
pub fn read_frame_raw(r: &mut impl Read) -> Result<(Vec<u8>, u64)> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)
        .map_err(|e| Error::new(e.kind(), format!("distributed frame: reading length: {e}")))?;
    let len = u64::from_le_bytes(b);
    if len > MAX_FRAME_BYTES {
        return Err(Error::new(
            ErrorKind::InvalidData,
            format!("distributed frame: length {len} exceeds the {MAX_FRAME_BYTES}-byte limit"),
        ));
    }
    r.read_exact(&mut b)
        .map_err(|e| Error::new(e.kind(), format!("distributed frame: reading checksum: {e}")))?;
    let hash = u64::from_le_bytes(b);
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).map_err(|e| {
        Error::new(e.kind(), format!("distributed frame: reading {len}-byte payload: {e}"))
    })?;
    Ok((payload, hash))
}

/// Verify a frame's checksum against its (possibly corrupted) payload.
pub fn verify_frame(payload: &[u8], hash: u64) -> Result<()> {
    let got = fnv1a64(payload);
    if got != hash {
        return Err(Error::new(
            ErrorKind::InvalidData,
            format!(
                "distributed frame: checksum mismatch over {} payload byte(s) \
                 (got {got:#018x}, frame claims {hash:#018x})",
                payload.len()
            ),
        ));
    }
    Ok(())
}

/// Read + verify + decode one message — the happy-path receive.
pub fn recv_msg(r: &mut impl Read) -> Result<Msg> {
    let (payload, hash) = read_frame_raw(r)?;
    verify_frame(&payload, hash)?;
    Msg::decode(&payload)
}

/// Encode + frame + write one message.
pub fn send_msg(w: &mut impl Write, msg: &Msg) -> Result<()> {
    write_frame(w, &msg.encode())
}

// ---- payload reader ----------------------------------------------------

/// Positional reader over one frame's payload, mirroring the
/// checkpoint codec's `CountingReader`: every failure names the byte
/// offset, and element counts are guarded against the bytes remaining
/// in the payload before anything is allocated.
pub struct FrameReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> FrameReader<'a> {
    pub fn new(buf: &'a [u8]) -> FrameReader<'a> {
        FrameReader { buf, pos: 0 }
    }

    /// Validate a length prefix of `count` elements, each at least
    /// `elem_bytes` on the wire, against the payload bytes left.
    pub fn guard_count(&self, count: u64, elem_bytes: u64, what: &str) -> Result<usize> {
        let remaining = (self.buf.len() - self.pos) as u64;
        if count.saturating_mul(elem_bytes) > remaining {
            return Err(self.bad(format!(
                "{what} count {count} needs more than the {remaining} byte(s) \
                 left in the frame"
            )));
        }
        Ok(count as usize)
    }

    fn fill(&mut self, out: &mut [u8]) -> Result<()> {
        let end = self.pos + out.len();
        if end > self.buf.len() {
            return Err(Error::new(
                ErrorKind::UnexpectedEof,
                format!(
                    "distributed frame: needed {} byte(s) at byte offset {} but the \
                     {}-byte payload ends first",
                    out.len(),
                    self.pos,
                    self.buf.len()
                ),
            ));
        }
        out.copy_from_slice(&self.buf[self.pos..end]);
        self.pos = end;
        Ok(())
    }

    pub fn u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.fill(&mut b)?;
        Ok(b[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.fill(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.fill(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    pub fn f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.fill(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }

    pub fn bad(&self, msg: String) -> Error {
        Error::new(
            ErrorKind::InvalidData,
            format!("distributed frame: {msg} (at byte offset {})", self.pos),
        )
    }
}

// ---- payload writer helpers --------------------------------------------

fn pu8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}
fn pu32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn pu64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn pf64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

// ---- plane codec -------------------------------------------------------

/// Encode one plane, repr-preserving (repr byte 0 = dense, 1 = sparse —
/// the checkpoint codec's convention). Values travel as raw `f64` bits,
/// so planes round-trip bitwise.
fn encode_plane(out: &mut Vec<u8>, p: &Plane) {
    pf64(out, p.off);
    pu64(out, p.tag);
    match &p.star {
        PlaneVec::Dense(v) => {
            pu8(out, 0);
            pu64(out, v.len() as u64);
            for &x in v {
                pf64(out, x);
            }
        }
        PlaneVec::Sparse { dim, idx, val } => {
            pu8(out, 1);
            pu64(out, *dim as u64);
            pu64(out, idx.len() as u64);
            for (&j, &x) in idx.iter().zip(val) {
                pu32(out, j);
                pf64(out, x);
            }
        }
    }
}

fn decode_plane(r: &mut FrameReader) -> Result<Plane> {
    let off = r.f64()?;
    let tag = r.u64()?;
    let star = match r.u8()? {
        0 => {
            let claimed = r.u64()?;
            let len = r.guard_count(claimed, 8, "dense plane payload")?;
            let mut v = Vec::with_capacity(len);
            for _ in 0..len {
                v.push(r.f64()?);
            }
            PlaneVec::Dense(v)
        }
        1 => {
            let dim = r.u64()? as usize;
            let claimed = r.u64()?;
            let nnz = r.guard_count(claimed, 12, "sparse plane entry")?;
            let mut idx = Vec::with_capacity(nnz);
            let mut val = Vec::with_capacity(nnz);
            for _ in 0..nnz {
                let j = r.u32()?;
                if j as usize >= dim {
                    return Err(r.bad(format!("sparse index {j} out of {dim}-d plane")));
                }
                idx.push(j);
                val.push(r.f64()?);
            }
            PlaneVec::Sparse { dim, idx, val }
        }
        other => return Err(r.bad(format!("unknown plane repr byte {other}"))),
    };
    Ok(Plane::new(star, off, tag))
}

fn encode_fault_stats(out: &mut Vec<u8>, s: &FaultStats) {
    pu64(out, s.injected);
    pu64(out, s.panics);
    pu64(out, s.transients);
    pu64(out, s.timeouts);
    pu64(out, s.slowdowns);
    pu64(out, s.retries);
    pu64(out, s.failed_calls);
}

fn decode_fault_stats(r: &mut FrameReader) -> Result<FaultStats> {
    Ok(FaultStats {
        injected: r.u64()?,
        panics: r.u64()?,
        transients: r.u64()?,
        timeouts: r.u64()?,
        slowdowns: r.u64()?,
        retries: r.u64()?,
        failed_calls: r.u64()?,
    })
}

// ---- messages ----------------------------------------------------------

const TAG_HELLO: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_WORK: u8 = 3;
const TAG_PLANES: u8 = 4;
/// Visible to the driver: heartbeats are recognised by tag *before* the
/// fault-injection boundary so the plan only ever sabotages real replies.
pub(super) const TAG_HEARTBEAT: u8 = 5;
const TAG_SHUTDOWN: u8 = 6;

/// One protocol message. See the module docs for the round structure.
#[derive(Clone, Debug)]
pub enum Msg {
    /// Worker → coordinator, first frame after (re)connecting.
    Hello { worker: u64, protocol: u64 },
    /// Coordinator → worker, handshake acknowledgement. `n_workers` is
    /// the initial cluster size — the per-run residue-class modulus the
    /// worker uses for its `block % n_workers` arena pinning.
    Welcome { worker: u64, n_workers: u64 },
    /// Coordinator → worker: one shard of an exact pass. `round` is the
    /// outer pass number stamping the `w` snapshot (resends of the same
    /// round carry the identical snapshot).
    Work { round: u64, w: Vec<f64>, blocks: Vec<u64> },
    /// Worker → coordinator: the shard's order-aligned results. A
    /// `None` plane is an oracle call that exhausted its retry budget
    /// worker-side (the coordinator requeues the block). `calls_total`
    /// is the worker's cumulative oracle-ledger count (folded only in
    /// multi-process mode); `fault_delta`/`penalty_secs` are the
    /// worker-side recovery counters accrued since its last reply.
    Planes {
        round: u64,
        worker: u64,
        planes: Vec<(u64, Option<Plane>)>,
        calls_total: u64,
        shard_secs: f64,
        fault_delta: FaultStats,
        penalty_secs: f64,
    },
    /// Worker → coordinator: still alive, still computing `round`.
    Heartbeat { round: u64 },
    /// Coordinator → worker: training is done, exit cleanly.
    Shutdown,
}

impl Msg {
    /// Serialize to a frame payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Msg::Hello { worker, protocol } => {
                pu8(&mut out, TAG_HELLO);
                pu64(&mut out, *worker);
                pu64(&mut out, *protocol);
            }
            Msg::Welcome { worker, n_workers } => {
                pu8(&mut out, TAG_WELCOME);
                pu64(&mut out, *worker);
                pu64(&mut out, *n_workers);
            }
            Msg::Work { round, w, blocks } => {
                pu8(&mut out, TAG_WORK);
                pu64(&mut out, *round);
                pu64(&mut out, w.len() as u64);
                for &x in w {
                    pf64(&mut out, x);
                }
                pu64(&mut out, blocks.len() as u64);
                for &b in blocks {
                    pu64(&mut out, b);
                }
            }
            Msg::Planes { round, worker, planes, calls_total, shard_secs, fault_delta, penalty_secs } => {
                pu8(&mut out, TAG_PLANES);
                pu64(&mut out, *round);
                pu64(&mut out, *worker);
                pu64(&mut out, planes.len() as u64);
                for (block, plane) in planes {
                    pu64(&mut out, *block);
                    match plane {
                        Some(p) => {
                            pu8(&mut out, 1);
                            encode_plane(&mut out, p);
                        }
                        None => pu8(&mut out, 0),
                    }
                }
                pu64(&mut out, *calls_total);
                pf64(&mut out, *shard_secs);
                encode_fault_stats(&mut out, fault_delta);
                pf64(&mut out, *penalty_secs);
            }
            Msg::Heartbeat { round } => {
                pu8(&mut out, TAG_HEARTBEAT);
                pu64(&mut out, *round);
            }
            Msg::Shutdown => pu8(&mut out, TAG_SHUTDOWN),
        }
        out
    }

    /// Decode a frame payload. Fails with an offset-naming error on
    /// truncated or structurally corrupt payloads; element counts are
    /// guarded against the payload size before allocation.
    pub fn decode(payload: &[u8]) -> Result<Msg> {
        let mut r = FrameReader::new(payload);
        let msg = match r.u8()? {
            TAG_HELLO => Msg::Hello { worker: r.u64()?, protocol: r.u64()? },
            TAG_WELCOME => Msg::Welcome { worker: r.u64()?, n_workers: r.u64()? },
            TAG_WORK => {
                let round = r.u64()?;
                let claimed = r.u64()?;
                let wlen = r.guard_count(claimed, 8, "weight snapshot")?;
                let mut w = Vec::with_capacity(wlen);
                for _ in 0..wlen {
                    w.push(r.f64()?);
                }
                let claimed = r.u64()?;
                let blen = r.guard_count(claimed, 8, "block shard")?;
                let mut blocks = Vec::with_capacity(blen);
                for _ in 0..blen {
                    blocks.push(r.u64()?);
                }
                Msg::Work { round, w, blocks }
            }
            TAG_PLANES => {
                let round = r.u64()?;
                let worker = r.u64()?;
                // Each entry is at least block(8) + present(1) bytes.
                let claimed = r.u64()?;
                let plen = r.guard_count(claimed, 9, "plane result")?;
                let mut planes = Vec::with_capacity(plen);
                for _ in 0..plen {
                    let block = r.u64()?;
                    let plane = match r.u8()? {
                        0 => None,
                        1 => Some(decode_plane(&mut r)?),
                        other => {
                            return Err(r.bad(format!("unknown plane presence byte {other}")))
                        }
                    };
                    planes.push((block, plane));
                }
                Msg::Planes {
                    round,
                    worker,
                    planes,
                    calls_total: r.u64()?,
                    shard_secs: r.f64()?,
                    fault_delta: decode_fault_stats(&mut r)?,
                    penalty_secs: r.f64()?,
                }
            }
            TAG_HEARTBEAT => Msg::Heartbeat { round: r.u64()? },
            TAG_SHUTDOWN => Msg::Shutdown,
            other => return Err(r.bad(format!("unknown message tag {other}"))),
        };
        if r.pos != payload.len() {
            return Err(r.bad(format!(
                "{} trailing byte(s) after a complete message",
                payload.len() - r.pos
            )));
        }
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_planes_msg() -> Msg {
        Msg::Planes {
            round: 3,
            worker: 1,
            planes: vec![
                (
                    7,
                    Some(Plane::new(
                        PlaneVec::Sparse {
                            dim: 10,
                            idx: vec![1, 4, 9],
                            val: vec![0.5, -2.25, 1e-3],
                        },
                        -1.5,
                        42,
                    )),
                ),
                (4, None),
                (1, Some(Plane::new(PlaneVec::Dense(vec![0.0, 1.0, -3.5]), 0.25, 7))),
            ],
            calls_total: 120,
            shard_secs: 0.125,
            fault_delta: FaultStats { injected: 2, retries: 1, ..FaultStats::default() },
            penalty_secs: 0.5,
        }
    }

    fn assert_planes_eq(a: &Msg, b: &Msg) {
        let (Msg::Planes { planes: pa, calls_total: ca, fault_delta: fa, .. },
             Msg::Planes { planes: pb, calls_total: cb, fault_delta: fb, .. }) = (a, b)
        else {
            panic!("not Planes messages");
        };
        assert_eq!(ca, cb);
        assert_eq!(fa, fb);
        assert_eq!(pa.len(), pb.len());
        for ((ba, qa), (bb, qb)) in pa.iter().zip(pb) {
            assert_eq!(ba, bb);
            match (qa, qb) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.off.to_bits(), y.off.to_bits());
                    assert_eq!(x.tag, y.tag);
                    assert_eq!(x.star.mem_bytes(), y.star.mem_bytes());
                }
                _ => panic!("plane presence diverged"),
            }
        }
    }

    #[test]
    fn messages_roundtrip_through_frames() {
        let msgs = vec![
            Msg::Hello { worker: 2, protocol: PROTOCOL_VERSION },
            Msg::Welcome { worker: 2, n_workers: 4 },
            Msg::Work { round: 9, w: vec![1.0, -0.5, 3.25], blocks: vec![0, 5, 10] },
            sample_planes_msg(),
            Msg::Heartbeat { round: 9 },
            Msg::Shutdown,
        ];
        let mut buf = Vec::new();
        for m in &msgs {
            send_msg(&mut buf, m).unwrap();
        }
        let mut r = &buf[..];
        for want in &msgs {
            let got = recv_msg(&mut r).unwrap();
            match (want, got) {
                (Msg::Hello { worker, protocol }, Msg::Hello { worker: w2, protocol: p2 }) => {
                    assert_eq!((*worker, *protocol), (w2, p2));
                }
                (Msg::Welcome { worker, n_workers }, Msg::Welcome { worker: w2, n_workers: n2 }) => {
                    assert_eq!((*worker, *n_workers), (w2, n2));
                }
                (Msg::Work { round, w, blocks }, Msg::Work { round: r2, w: w2, blocks: b2 }) => {
                    assert_eq!(*round, r2);
                    let bits: Vec<u64> = w.iter().map(|x| x.to_bits()).collect();
                    let bits2: Vec<u64> = w2.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(bits, bits2, "snapshot must round-trip bitwise");
                    assert_eq!(*blocks, b2);
                }
                (a @ Msg::Planes { .. }, ref b @ Msg::Planes { .. }) => assert_planes_eq(a, b),
                (Msg::Heartbeat { round }, Msg::Heartbeat { round: r2 }) => {
                    assert_eq!(*round, r2)
                }
                (Msg::Shutdown, Msg::Shutdown) => {}
                (w, g) => panic!("message kind diverged: want {w:?}, got {g:?}"),
            }
        }
        assert!(r.is_empty(), "no trailing bytes");
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        buf.extend_from_slice(&0u64.to_le_bytes());
        let err = read_frame_raw(&mut &buf[..]).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidData);
        assert!(err.to_string().contains("exceeds"), "unexpected error: {err}");
    }

    #[test]
    fn garbled_payload_fails_the_checksum() {
        let payload = sample_planes_msg().encode();
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        // Flip one payload byte (a value byte that would decode fine).
        let last = buf.len() - 1;
        buf[last] ^= 0x01;
        let (payload, hash) = read_frame_raw(&mut &buf[..]).unwrap();
        let err = verify_frame(&payload, hash).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "unexpected error: {err}");
    }

    #[test]
    fn truncated_payload_errors_name_the_byte_offset() {
        let payload = sample_planes_msg().encode();
        for cut in [1usize, payload.len() / 4, payload.len() / 2, payload.len() - 1] {
            let err = Msg::decode(&payload[..cut]).unwrap_err();
            let text = err.to_string();
            assert!(
                text.contains("byte offset") || text.contains("left in the frame"),
                "cut at {cut}: error must name an offset, got: {text}"
            );
        }
    }

    #[test]
    fn corrupt_inner_count_is_guarded_not_allocated() {
        // A Work frame whose snapshot length claims far more payload
        // than the frame carries: the guard must reject it by offset.
        let mut payload = Vec::new();
        payload.push(3u8); // TAG_WORK
        payload.extend_from_slice(&1u64.to_le_bytes()); // round
        payload.extend_from_slice(&u64::MAX.to_le_bytes()); // poisoned w-len
        let err = Msg::decode(&payload).unwrap_err();
        let text = err.to_string();
        assert!(text.contains("weight snapshot count"), "unexpected error: {text}");
        assert!(text.contains("byte offset"), "unexpected error: {text}");
    }

    #[test]
    fn unknown_tag_and_trailing_bytes_are_rejected() {
        let err = Msg::decode(&[99u8]).unwrap_err();
        assert!(err.to_string().contains("unknown message tag"));
        let mut payload = Msg::Shutdown.encode();
        payload.push(0);
        let err = Msg::decode(&payload).unwrap_err();
        assert!(err.to_string().contains("trailing"));
    }

    #[test]
    fn fnv_is_stable_and_input_sensitive() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
    }
}

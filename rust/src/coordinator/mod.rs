//! The paper's contribution: Frank-Wolfe-family optimizers over the SSVM
//! dual, with multi-plane working sets, automatic parameter selection,
//! inner-product caching and iterate averaging, plus classic baselines.
pub mod dual;
pub mod working_set;
pub mod sampling;
pub mod auto;
pub mod products;
pub mod averaging;
pub mod fw;
pub mod bcfw;
pub mod mp_bcfw;
pub mod async_overlap;
pub mod parallel;
pub mod metrics;
pub mod trainer;
pub mod baselines;
pub mod checkpoint;
pub mod distributed;
pub mod faults;
pub mod kernel;
pub mod kernel_bcfw;

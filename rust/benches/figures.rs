//! End-to-end benchmark: regenerates every figure and table of the
//! paper's evaluation at bench scale and writes CSVs into `results/`.
//! Run with `cargo bench --bench figures` (or `make figures` for the
//! larger CLI-driven variant with paper parameters).
//!
//! Environment knobs:
//!   MPBCFW_BENCH_SCALE   tiny|small|paper   (default small)
//!   MPBCFW_BENCH_REPEATS integer            (default 5)
//!   MPBCFW_BENCH_ITERS   integer            (default 20)

use mpbcfw::bench::figures::{run_figures, FigureOpts};
use mpbcfw::bench::tables::run_table;
use mpbcfw::coordinator::trainer::DatasetKind;
use mpbcfw::data::types::Scale;

fn env_or(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

fn main() -> anyhow::Result<()> {
    let opts = FigureOpts {
        scale: Scale::parse(&env_or("MPBCFW_BENCH_SCALE", "small")).expect("bad scale"),
        repeats: env_or("MPBCFW_BENCH_REPEATS", "5").parse()?,
        max_iters: env_or("MPBCFW_BENCH_ITERS", "20").parse()?,
        ..Default::default()
    };
    let out = std::path::Path::new("results");
    let log = |m: String| println!("{m}");
    println!(
        "regenerating paper evaluation (scale={}, repeats={}, iters={})",
        opts.scale.name(),
        opts.repeats,
        opts.max_iters
    );
    run_figures("all", &DatasetKind::all(), &opts, out, log)?;
    run_table("all", &DatasetKind::all(), &opts, out, |m| println!("{m}"))?;
    println!("done; CSVs in results/");
    Ok(())
}

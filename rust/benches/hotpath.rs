//! Micro-benchmarks of the coordinator hot paths (hand-rolled harness;
//! criterion is unavailable offline). Run with `cargo bench --bench
//! hotpath`. Each benchmark reports median ns/op over repeated batches —
//! these are the numbers the §Perf log in EXPERIMENTS.md tracks.

use std::time::Instant;

use mpbcfw::coordinator::dual::DualState;
use mpbcfw::coordinator::parallel;
use mpbcfw::coordinator::products::{
    cached_block_updates, cached_block_updates_with, BlockProducts, GramCache, ProductMode,
    ProductStats,
};
use mpbcfw::coordinator::working_set::WorkingSet;
use mpbcfw::data::synth::{horseseg_like, ocr_like, usps_like};
use mpbcfw::data::types::Scale;
use mpbcfw::maxflow::BkGraph;
use mpbcfw::model::plane::{Plane, PlaneVec};
use mpbcfw::model::problem::StructuredProblem;
use mpbcfw::model::scratch::OracleScratch;
use mpbcfw::oracle::graphcut::GraphCutProblem;
use mpbcfw::oracle::multiclass::MulticlassProblem;
use mpbcfw::oracle::sequence::SequenceProblem;
use mpbcfw::oracle::wrappers::CountingOracle;
use mpbcfw::runtime::engine::{NativeEngine, ScoringEngine};
use mpbcfw::utils::math::{self, KernelBackend};
use mpbcfw::utils::rng::Pcg;

/// Time `f` over enough iterations for stable numbers; returns ns/op.
fn bench<F: FnMut()>(name: &str, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..3 {
        f();
    }
    let mut best = f64::INFINITY;
    for _round in 0..5 {
        let mut iters = 1u64;
        loop {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t.elapsed().as_secs_f64();
            if dt > 0.02 {
                best = best.min(dt * 1e9 / iters as f64);
                break;
            }
            iters *= 4;
        }
    }
    println!("{name:44} {best:14.0} ns/op");
    best
}

fn main() {
    println!("== hotpath micro-benchmarks (ns/op, best of 5 rounds) ==");
    let mut eng = NativeEngine;
    let rng = &mut Pcg::seeded(7);

    // -- dense math kernels (scalar vs simd A/B) -----------------------
    let a: Vec<f64> = (0..2561).map(|_| rng.normal()).collect();
    let b: Vec<f64> = (0..2561).map(|_| rng.normal()).collect();
    bench("dot 2561-d (scalar)", || {
        std::hint::black_box(math::dot_with(KernelBackend::Scalar, &a, &b));
    });
    bench("dot 2561-d (simd)", || {
        std::hint::black_box(math::dot_with(KernelBackend::Simd, &a, &b));
    });
    let mut acc = vec![0.0f64; 2561];
    bench("axpy 2561-d (scalar)", || {
        math::axpy_with(KernelBackend::Scalar, 0.5, &a, &mut acc);
        std::hint::black_box(&acc);
    });
    bench("axpy 2561-d (simd)", || {
        math::axpy_with(KernelBackend::Simd, 0.5, &a, &mut acc);
        std::hint::black_box(&acc);
    });

    // -- oracles -------------------------------------------------------
    let usps = MulticlassProblem::new(usps_like::generate(
        usps_like::UspsLikeConfig::at_scale(Scale::Small),
        0,
    ));
    let w: Vec<f64> = (0..usps.dim()).map(|_| 0.01 * rng.normal()).collect();
    let mut i = 0;
    bench("oracle usps_like (explicit argmax)", || {
        i = (i + 1) % usps.n();
        std::hint::black_box(usps.oracle(i, &w, &mut eng));
    });

    let ocr = SequenceProblem::new(ocr_like::generate(
        ocr_like::OcrLikeConfig::at_scale(Scale::Small),
        0,
    ));
    let w2: Vec<f64> = (0..ocr.dim()).map(|_| 0.01 * rng.normal()).collect();
    bench("oracle ocr_like (Viterbi)", || {
        i = (i + 1) % ocr.n();
        std::hint::black_box(ocr.oracle(i, &w2, &mut eng));
    });

    let seg = GraphCutProblem::new(horseseg_like::generate(
        horseseg_like::HorseSegLikeConfig::at_scale(Scale::Small),
        0,
    ));
    let w3: Vec<f64> = (0..seg.dim()).map(|_| 0.01 * rng.normal()).collect();
    bench("oracle horseseg_like (BK min-cut, cold)", || {
        i = (i + 1) % seg.n();
        std::hint::black_box(seg.oracle(i, &w3, &mut eng));
    });

    // Warm-start A/B: persistent per-example graphs + reused buffers
    // (the --oracle-reuse on path). Identical planes; only the per-call
    // construction work disappears.
    let mut warm = OracleScratch::new(true);
    bench("oracle horseseg_like (BK min-cut, warm)", || {
        i = (i + 1) % seg.n();
        std::hint::black_box(seg.oracle_scratch(i, &w3, &mut eng, &mut warm));
    });

    // -- BK max-flow on a 16x16 grid -----------------------------------
    bench("bk maxflow 256-node grid", || {
        let mut g = BkGraph::new(256, 480);
        let mut r2 = Pcg::seeded(3);
        for v in 0..256u32 {
            g.add_tweights(v, r2.f64() * 2.0, r2.f64() * 2.0);
        }
        for r in 0..16u32 {
            for c in 0..16u32 {
                let id = r * 16 + c;
                if c + 1 < 16 {
                    g.add_edge(id, id + 1, 1.0, 1.0);
                }
                if r + 1 < 16 {
                    g.add_edge(id, id + 16, 1.0, 1.0);
                }
            }
        }
        std::hint::black_box(g.maxflow());
    });

    // -- approximate pass: plain vs product-cached ----------------------
    let dim = 1509; // ocr_like small dim+1 territory
    let mk_ws = |rng: &mut Pcg, m: usize| {
        let mut ws = WorkingSet::new(1000);
        for t in 0..m {
            let pairs: Vec<(u32, f64)> =
                (0..200).map(|_| (rng.below(dim) as u32, rng.normal())).collect();
            ws.insert(Plane::new(PlaneVec::sparse(dim, pairs), rng.normal(), t as u64), 0);
        }
        ws
    };
    let mut st = DualState::new(4, dim, 0.01);
    let ws = mk_ws(rng, 12);
    bench("approx step plain (12 planes, nnz 200)", || {
        st.refresh_w();
        if let Some((j, _)) = ws.best_at(&st.w) {
            let g = st.block_step_ref(0, ws.plane_ref(j));
            std::hint::black_box(g);
        }
    });

    let mut gram = GramCache::new();
    let mut st2 = DualState::new(4, dim, 0.01);
    let mut ws2 = mk_ws(rng, 12);
    let mut now = 0u64;
    let mut coef_scratch: Vec<f64> = Vec::new();
    bench("approx block cached r=10 (12 planes)", || {
        now += 1;
        std::hint::black_box(cached_block_updates(
            &mut st2,
            &mut ws2,
            &mut gram,
            0,
            10,
            now,
            &mut coef_scratch,
        ));
    });

    // Product maintenance A/B: the recompute visit above pays the dense
    // Θ(|W|·d) product pass every call; the incremental visit starts
    // warm from persisted scalars (zero dense dots, monotone-guarded).
    // Once this fixed state converges, zero-step warm visits trigger
    // the stall-refresh every few calls, so the number below blends
    // ~3/4 warm visits with ~1/4 dense stall-refreshes — still the
    // honest per-visit cost of the incremental mode on a static block.
    let mut gram3 = GramCache::new();
    let mut st3 = DualState::new(4, dim, 0.01);
    let mut ws3 = mk_ws(rng, 12);
    let mut prod = BlockProducts::new();
    let mut stats = ProductStats::default();
    let mut now3 = 0u64;
    bench("approx block warm incremental r=10", || {
        now3 += 1;
        std::hint::black_box(cached_block_updates_with(
            &mut st3,
            &mut ws3,
            &mut gram3,
            0,
            10,
            now3,
            &mut coef_scratch,
            ProductMode::Incremental,
            0, // no periodic refresh: every visit after the first is warm
            &mut prod,
            &mut stats,
            KernelBackend::Scalar,
        ));
    });

    // -- kernel-backend A/B on the cached product pass ------------------
    // Recompute mode pays the dense Θ(|W|·d) product pass every visit,
    // so the dot/fused kernels dominate — the honest scalar-vs-simd
    // comparison. One pair per scenario dimensionality; both backends
    // see byte-identical working sets (fresh seeded RNG per scenario).
    let scenarios: [(&str, usize); 3] =
        [("usps_like", usps.dim()), ("ocr_like", ocr.dim()), ("horseseg_like", seg.dim())];
    for (name, sdim) in scenarios {
        for kernel in [KernelBackend::Scalar, KernelBackend::Simd] {
            let mut srng = Pcg::seeded(11 + sdim as u64);
            let mut wsk = WorkingSet::new(1000);
            for t in 0..12 {
                let nnz = (sdim / 4).clamp(32, 200);
                let pairs: Vec<(u32, f64)> =
                    (0..nnz).map(|_| (srng.below(sdim) as u32, srng.normal())).collect();
                wsk.insert(
                    Plane::new(PlaneVec::sparse(sdim, pairs), srng.normal(), t as u64),
                    0,
                );
            }
            let mut gramk = GramCache::new();
            let mut stk = DualState::new(4, sdim, 0.01);
            let mut prodk = BlockProducts::new();
            let mut statsk = ProductStats::default();
            let mut nowk = 0u64;
            bench(
                &format!("approx block recompute {name} ({})", kernel.name()),
                || {
                    nowk += 1;
                    std::hint::black_box(cached_block_updates_with(
                        &mut stk,
                        &mut wsk,
                        &mut gramk,
                        0,
                        10,
                        nowk,
                        &mut coef_scratch,
                        ProductMode::Recompute,
                        0,
                        &mut prodk,
                        &mut statsk,
                        kernel,
                    ));
                },
            );
        }
    }

    // -- parallel sharded exact-pass dispatch (threads sweep) -----------
    // The paper's costliest oracle (graph cut) is where sharding pays:
    // one "op" here is a full exact pass over the dataset.
    let segc = CountingOracle::new(Box::new(GraphCutProblem::new(horseseg_like::generate(
        horseseg_like::HorseSegLikeConfig::at_scale(Scale::Small),
        0,
    ))));
    let wseg: Vec<f64> = (0..segc.dim()).map(|_| 0.01 * rng.normal()).collect();
    let order: Vec<usize> = (0..segc.n()).collect();
    let mut sweep: Vec<(usize, f64)> = Vec::new();
    for threads in [1usize, 2, 4] {
        let ns = bench(&format!("exact pass horseseg_like ({threads} threads)"), || {
            std::hint::black_box(parallel::exact_pass(&segc, &wseg, &order, threads));
        });
        sweep.push((threads, ns));
    }
    let base_ns = sweep[0].1;
    for &(threads, ns) in &sweep[1..] {
        println!(
            "{:44} {:14.2} x",
            format!("  oracle-dispatch speedup @ {threads} threads"),
            base_ns / ns
        );
    }

    // -- engine scoring paths -------------------------------------------
    let mat: Vec<f64> = (0..64 * 2561).map(|_| rng.normal()).collect();
    let v: Vec<f64> = (0..2561).map(|_| rng.normal()).collect();
    let mut out = Vec::new();
    bench("native matvec 64x2561", || {
        eng.matvec(&mat, 64, 2561, &v, &mut out);
        std::hint::black_box(&out);
    });
}

//! Integration coverage for the matrix-free approximate-pass layer
//! (slab working set + triangular Gram arena + incremental product
//! maintenance):
//!
//! * the bitwise anchor — under `--products recompute`, the slot-keyed
//!   triangular Gram arena follows the legacy id-keyed hashmap path
//!   **bit for bit** on horseseg_like and ocr_like same-seed
//!   trajectories. The hashmap+recompute combination *is* the pre-slab
//!   code path (the slab stores the same payload representations and
//!   every kernel accumulates in the same order), so this pins the
//!   whole storage refactor as value-neutral;
//! * the incremental contract — `--products incremental` (the default)
//!   runs warm visits with zero dense product passes
//!   (`product_refreshes` < `cached_visits`), keeps the dual monotone
//!   (the O(d) guard), and lands within a stated drift bound of the
//!   recompute trajectory with the refresh guard on;
//! * determinism — incremental mode has no timing dependence, so fixed
//!   seeds reproduce exactly.

use mpbcfw::coordinator::products::{GramBackend, ProductMode};
use mpbcfw::coordinator::trainer::{train, Algo, DatasetKind, TrainSpec};
use mpbcfw::data::types::Scale;

fn spec(ds: DatasetKind, gram: GramBackend, products: ProductMode) -> TrainSpec {
    TrainSpec {
        dataset: ds,
        scale: Scale::Tiny,
        algo: Algo::MpBcfw,
        max_iters: 5,
        seed: 13,
        data_seed: 4,
        // The §3.4 slope rule is timing-based; pin the pass schedule so
        // every variant executes the identical visit sequence.
        auto_approx: false,
        max_approx_passes: 3,
        gram,
        products,
        ..Default::default()
    }
}

fn assert_bitwise_equal_series(
    a: &mpbcfw::coordinator::metrics::Series,
    b: &mpbcfw::coordinator::metrics::Series,
    what: &str,
) {
    assert_eq!(a.points.len(), b.points.len(), "{what}: point counts differ");
    for (p, q) in a.points.iter().zip(&b.points) {
        assert_eq!(p.outer, q.outer);
        assert_eq!(p.oracle_calls, q.oracle_calls, "{what} at outer {}", p.outer);
        assert_eq!(p.primal, q.primal, "{what}: primal diverged at outer {}", p.outer);
        assert_eq!(p.dual, q.dual, "{what}: dual diverged at outer {}", p.outer);
        assert_eq!(p.approx_passes, q.approx_passes);
        assert_eq!(p.approx_steps, q.approx_steps, "{what} at outer {}", p.outer);
        assert_eq!(p.ws_mean, q.ws_mean);
        assert!(
            p.gap_est == q.gap_est || (p.gap_est.is_nan() && q.gap_est.is_nan()),
            "{what}: gap_est diverged at outer {}: {} vs {}",
            p.outer,
            p.gap_est,
            q.gap_est
        );
    }
}

#[test]
fn triangular_recompute_bitwise_matches_hashmap_on_horseseg_like() {
    let map = train(&spec(DatasetKind::HorsesegLike, GramBackend::Hashmap, ProductMode::Recompute))
        .unwrap();
    let tri =
        train(&spec(DatasetKind::HorsesegLike, GramBackend::Triangular, ProductMode::Recompute))
            .unwrap();
    assert_bitwise_equal_series(&map, &tri, "horseseg_like gram backends");
}

#[test]
fn triangular_recompute_bitwise_matches_hashmap_on_ocr_like() {
    let map =
        train(&spec(DatasetKind::OcrLike, GramBackend::Hashmap, ProductMode::Recompute)).unwrap();
    let tri = train(&spec(DatasetKind::OcrLike, GramBackend::Triangular, ProductMode::Recompute))
        .unwrap();
    assert_bitwise_equal_series(&map, &tri, "ocr_like gram backends");
}

#[test]
fn incremental_runs_warm_visits_within_drift_bound_of_recompute() {
    for ds in [DatasetKind::OcrLike, DatasetKind::UspsLike] {
        let rec =
            train(&spec(ds, GramBackend::Triangular, ProductMode::Recompute)).unwrap();
        let inc =
            train(&spec(ds, GramBackend::Triangular, ProductMode::Incremental)).unwrap();
        // Both modes keep the dual monotone (incremental via the O(d)
        // monotone guard on every warm materialization) and weakly dual.
        for s in [&rec, &inc] {
            for w in s.points.windows(2) {
                assert!(w[1].dual >= w[0].dual - 1e-10, "{ds:?}: dual decreased {w:?}");
            }
            let last = s.points.last().unwrap();
            assert!(last.primal >= last.dual - 1e-9, "{ds:?}: weak duality");
        }
        // Warm visits actually happened, and they did zero dense passes
        // (that is the definition of product_refreshes).
        let last_inc = inc.points.last().unwrap();
        assert!(last_inc.cached_visits > 0);
        assert!(
            last_inc.product_refreshes < last_inc.cached_visits,
            "{ds:?}: incremental never went warm ({}/{})",
            last_inc.product_refreshes,
            last_inc.cached_visits
        );
        let last_rec = rec.points.last().unwrap();
        assert_eq!(
            last_rec.product_refreshes, last_rec.cached_visits,
            "{ds:?}: recompute must pay the dense pass every visit"
        );
        // The stated drift bound: with the refresh guard on (default
        // K = 8) the incremental final dual stays within 5% relative of
        // the recompute final dual. Both runs share the exact-pass
        // oracle schedule, so the duals are directly comparable.
        let (fr, fi) = (last_rec.dual, last_inc.dual);
        assert!(
            (fr - fi).abs() <= 0.05 * fr.abs().max(fi.abs()).max(1e-12),
            "{ds:?}: incremental dual {fi} drifted beyond 5% of recompute {fr}"
        );
    }
}

#[test]
fn incremental_mode_is_deterministic_at_fixed_seed() {
    let a = train(&spec(DatasetKind::UspsLike, GramBackend::Triangular, ProductMode::Incremental))
        .unwrap();
    let b = train(&spec(DatasetKind::UspsLike, GramBackend::Triangular, ProductMode::Incremental))
        .unwrap();
    assert_bitwise_equal_series(&a, &b, "incremental determinism");
}

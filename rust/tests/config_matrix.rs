//! Pairwise-covering configuration matrix: a tiny-scale sweep over
//! threads × sampling × steps × products × gram × oracle-reuse ×
//! async × kernel × faults × dist. Full factorial is
//! 2·3·2·2·2·2·2·2·2·2 = 1536 runs; the 8 rows below cover every
//! *feasible* pair of factor levels (verified by
//! `rows_are_pairwise_covering`), which is where config-interaction
//! bugs live. One pair is excluded by construction: (dist=loopback,
//! async=on) — cluster rounds are bulk-synchronous, the trainer rejects
//! the combination, so the covering requirement for that factor pair is
//! the three feasible combos. Every row must train without panic with a
//! monotone dual and weak duality, and every async-off threads=4
//! **scalar faults-off dist=single** row must bitwise-match its
//! threads=1 twin (snapshot scoring + deterministic merge order make
//! the trajectory invariant across worker counts ≥ 1; threads=0 is the
//! freshest-w sequential path with a legitimately different trajectory,
//! so the twin is 1). Async-on rows overlap the oracle with the real
//! worker pool: fold timing is OS-scheduled, so they are checked
//! against the documented bounded-drift contract (monotone dual + weak
//! duality) rather than a bitwise twin. Simd rows likewise make no
//! bitwise claim — their reductions reassociate under the pinned fold
//! order (see `tests/kernel_backends.rs` for the lane contracts).
//! Faults-inject rows skip and requeue failed blocks, so they too are
//! held to monotone dual + weak duality here; their own bitwise
//! contracts (same-seed twins, thread-count invariance under injection)
//! live in `tests/fault_tolerance.rs`, and the loopback cluster's own
//! bitwise anchor (single ≡ 1+N processes) lives in
//! `tests/distributed.rs` — here loopback rows only prove the mode
//! *composes* with every other factor level.

use mpbcfw::coordinator::async_overlap::AsyncMode;
use mpbcfw::coordinator::distributed::DistMode;
use mpbcfw::coordinator::faults::FaultMode;
use mpbcfw::coordinator::products::{GramBackend, ProductMode};
use mpbcfw::coordinator::sampling::{SamplingStrategy, StepRule};
use mpbcfw::coordinator::trainer::{train, Algo, DatasetKind, TrainSpec};
use mpbcfw::data::types::Scale;
use mpbcfw::utils::math::KernelBackend;

struct Row {
    threads: usize,
    sampling: SamplingStrategy,
    steps: StepRule,
    products: ProductMode,
    gram: GramBackend,
    oracle_reuse: bool,
    async_mode: AsyncMode,
    kernel: KernelBackend,
    faults: FaultMode,
    dist: DistMode,
}

fn rows() -> Vec<Row> {
    use AsyncMode::{Off, On};
    use DistMode::{Loopback, Single};
    use FaultMode::Inject;
    use GramBackend::{Hashmap, Triangular};
    use KernelBackend::{Scalar, Simd};
    use ProductMode::{Incremental, Recompute};
    use SamplingStrategy::{Cyclic, GapProportional, Uniform};
    use StepRule::{Fw, Pairwise};
    #[allow(clippy::too_many_arguments)]
    let mk = |threads,
              sampling,
              steps,
              products,
              gram,
              oracle_reuse,
              async_mode,
              kernel,
              faults,
              dist| Row {
        threads,
        sampling,
        steps,
        products,
        gram,
        oracle_reuse,
        async_mode,
        kernel,
        faults,
        dist,
    };
    // Faults assignment: inject on rows 1–4, off on rows 0 and 5–7;
    // loopback on rows 1, 4 and 7 — necessarily all async-off, as the
    // trainer rejects (dist=loopback, async=on). Each partition spans
    // both thread levels, all three sampling levels and both levels of
    // every binary factor, so pair coverage holds (re-verified by
    // `rows_are_pairwise_covering`). Every inject and every loopback
    // row has threads ≥ 1, as the executor boundary requires, and
    // row 0 is the designated threads-twin row (threads=4, async off,
    // scalar, faults off, dist single).
    vec![
        mk(4, Uniform, Fw, Recompute, Hashmap, true, Off, Scalar, FaultMode::Off, Single),
        mk(4, Uniform, Pairwise, Incremental, Hashmap, false, Off, Simd, Inject, Loopback),
        mk(1, GapProportional, Pairwise, Recompute, Triangular, true, On, Simd, Inject, Single),
        mk(1, GapProportional, Fw, Incremental, Hashmap, true, On, Scalar, Inject, Single),
        mk(1, Cyclic, Fw, Incremental, Triangular, true, Off, Scalar, Inject, Loopback),
        mk(4, Cyclic, Pairwise, Recompute, Hashmap, false, On, Simd, FaultMode::Off, Single),
        mk(1, Uniform, Fw, Incremental, Triangular, false, On, Simd, FaultMode::Off, Single),
        mk(4, GapProportional, Pairwise, Recompute, Triangular, false, Off, Scalar, FaultMode::Off, Loopback),
    ]
}

fn spec_for(row: &Row, threads: usize) -> TrainSpec {
    TrainSpec {
        dataset: DatasetKind::UspsLike,
        scale: Scale::Tiny,
        algo: Algo::MpBcfw,
        seed: 7,
        max_iters: 3,
        // Pin the pass schedule: the §3.4 rule is wall-clock-driven and
        // would fork the twin trajectories under load.
        auto_approx: false,
        max_approx_passes: 2,
        threads,
        sampling: row.sampling,
        steps: row.steps,
        products: row.products,
        gram: row.gram,
        oracle_reuse: row.oracle_reuse,
        async_mode: row.async_mode,
        kernel: row.kernel,
        faults: row.faults,
        // Non-default fault knobs are only legal under inject (the
        // trainer rejects them otherwise). A fixed fault seed keeps
        // every inject row's schedule deterministic.
        fault_seed: if row.faults == FaultMode::Inject { 13 } else { 0 },
        fault_rate: if row.faults == FaultMode::Inject {
            0.5
        } else {
            mpbcfw::coordinator::faults::DEFAULT_FAULT_RATE
        },
        oracle_retries: if row.faults == FaultMode::Inject { 1 } else { 2 },
        eval_every: 1,
        // The remaining dist knobs (workers, transport faults,
        // straggler/reconnect budgets) keep their defaults: transport
        // sabotage has its own deterministic suite in
        // `tests/distributed.rs`; here loopback rows prove composition.
        dist: row.dist,
        ..Default::default()
    }
}

fn level_indices(r: &Row) -> [usize; 10] {
    [
        match r.threads {
            1 => 0,
            _ => 1,
        },
        match r.sampling {
            SamplingStrategy::Uniform => 0,
            SamplingStrategy::GapProportional => 1,
            SamplingStrategy::Cyclic => 2,
        },
        match r.steps {
            StepRule::Fw => 0,
            StepRule::Pairwise => 1,
        },
        match r.products {
            ProductMode::Recompute => 0,
            ProductMode::Incremental => 1,
        },
        match r.gram {
            GramBackend::Hashmap => 0,
            GramBackend::Triangular => 1,
        },
        usize::from(!r.oracle_reuse),
        match r.async_mode {
            AsyncMode::Off => 0,
            AsyncMode::On => 1,
        },
        match r.kernel {
            KernelBackend::Scalar => 0,
            KernelBackend::Simd => 1,
        },
        match r.faults {
            FaultMode::Off => 0,
            FaultMode::Inject => 1,
        },
        match r.dist {
            DistMode::Single => 0,
            DistMode::Loopback => 1,
        },
    ]
}

#[test]
fn rows_are_pairwise_covering() {
    let levels = [2usize, 3, 2, 2, 2, 2, 2, 2, 2, 2];
    // (async=on, dist=loopback) is infeasible — cluster rounds are
    // bulk-synchronous and the trainer rejects the combination — so the
    // async×dist pair must cover exactly the three feasible combos.
    const ASYNC: usize = 6;
    const DIST: usize = 9;
    let idx: Vec<[usize; 10]> = rows().iter().map(level_indices).collect();
    for row in &idx {
        assert!(
            (row[ASYNC], row[DIST]) != (1, 1),
            "matrix contains the infeasible (async=on, dist=loopback) combination"
        );
    }
    for i in 0..10 {
        for j in (i + 1)..10 {
            let mut seen = std::collections::HashSet::new();
            for row in &idx {
                seen.insert((row[i], row[j]));
            }
            let excluded = usize::from((i, j) == (ASYNC, DIST));
            assert_eq!(
                seen.len(),
                levels[i] * levels[j] - excluded,
                "factor pair ({i},{j}) not fully covered by the matrix"
            );
        }
    }
}

#[test]
fn every_row_trains_and_parallel_rows_match_their_sequential_twin() {
    for (k, row) in rows().iter().enumerate() {
        let s = train(&spec_for(row, row.threads))
            .unwrap_or_else(|e| panic!("row {k}: training failed: {e}"));
        assert!(!s.points.is_empty(), "row {k}: no eval points");
        for p in &s.points {
            assert!(p.primal >= p.dual - 1e-9, "row {k}: weak duality violated");
        }
        for w in s.points.windows(2) {
            assert!(
                w[1].dual >= w[0].dual - 1e-10,
                "row {k}: dual decreased {} -> {}",
                w[0].dual,
                w[1].dual
            );
        }
        // The bitwise threads-twin contract holds for the synchronous
        // scalar faults-off in-process driver only; async-on fold
        // timing is OS-scheduled, simd reductions reassociate,
        // faults-inject rows have their own bitwise contracts in
        // `tests/fault_tolerance.rs`, and loopback rows have their own
        // bitwise anchor (single-process ≡ cluster) in
        // `tests/distributed.rs` (the monotone/weak-duality checks
        // above are their contract here).
        if row.threads > 1
            && row.async_mode == AsyncMode::Off
            && row.kernel == KernelBackend::Scalar
            && row.faults == FaultMode::Off
            && row.dist == DistMode::Single
        {
            let twin = train(&spec_for(row, 1))
                .unwrap_or_else(|e| panic!("row {k}: twin failed: {e}"));
            let bits =
                |pts: &[mpbcfw::coordinator::metrics::EvalPoint]| -> Vec<(u64, u64, u64)> {
                    pts.iter()
                        .map(|p| (p.dual.to_bits(), p.primal.to_bits(), p.oracle_calls))
                        .collect()
                };
            assert_eq!(
                bits(&s.points),
                bits(&twin.points),
                "row {k}: threads={} trajectory diverged from its threads=1 twin",
                row.threads
            );
        }
    }
}

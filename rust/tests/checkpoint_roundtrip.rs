//! Run-checkpoint contract: a training run serialized mid-run and
//! reloaded into a fresh process resumes onto the *same* trajectory —
//! bitwise, in both `--products` modes — and a damaged checkpoint is
//! rejected with an error that names the failing byte offset.
//!
//! Scope guards (mirroring `checkpoint::load_run`): averaged runs are
//! refused (averagers are not serialized), and the suite pins
//! `StepRule::Fw` — the pairwise dust-prune walks a `HashMap`, so its
//! trajectory is not replay-stable across processes.

use std::io::Write as _;

use mpbcfw::coordinator::checkpoint::{load_run, save_run};
use mpbcfw::coordinator::metrics::Series;
use mpbcfw::coordinator::mp_bcfw::{self, MpBcfwConfig};
use mpbcfw::coordinator::products::ProductMode;
use mpbcfw::data::synth::usps_like::{generate, UspsLikeConfig};
use mpbcfw::data::types::Scale;
use mpbcfw::oracle::multiclass::MulticlassProblem;
use mpbcfw::oracle::wrappers::CountingOracle;
use mpbcfw::runtime::engine::NativeEngine;

fn tiny_problem() -> CountingOracle {
    CountingOracle::new(Box::new(MulticlassProblem::new(generate(
        UspsLikeConfig::at_scale(Scale::Tiny),
        1,
    ))))
}

fn cfg(max_iters: u64, products: ProductMode) -> MpBcfwConfig {
    MpBcfwConfig {
        max_iters,
        auto_approx: false,
        max_approx_passes: 2,
        seed: 7,
        products,
        ..MpBcfwConfig::mp_paper(1.0 / 60.0)
    }
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("mpbcfw_it_ckpt_{name}_{}", std::process::id()))
}

fn bits(s: &Series) -> Vec<(u64, u64, u64, u64)> {
    s.points
        .iter()
        .map(|p| (p.outer, p.dual.to_bits(), p.primal.to_bits(), p.oracle_calls))
        .collect()
}

#[test]
fn resumed_run_bitwise_matches_uninterrupted_run_in_both_product_modes() {
    for products in [ProductMode::Recompute, ProductMode::Incremental] {
        // Reference: one uninterrupted 8-iteration run.
        let full_cfg = cfg(8, products);
        let reference = tiny_problem();
        let mut eng = NativeEngine;
        let (full, _) = mp_bcfw::run(&reference, &mut eng, &full_cfg);

        // Interrupted: stop after 4, checkpoint, reload into a fresh
        // problem (fresh caches, fresh oracle arenas), resume to 8.
        let problem = tiny_problem();
        let (_, run) = mp_bcfw::run(&problem, &mut eng, &cfg(4, products));
        let path = tmp(&format!("resume_{products:?}"));
        save_run(&path, &run, &problem).expect("save_run failed");

        let fresh = tiny_problem();
        let mut reloaded = load_run(&path, &fresh, &full_cfg).expect("load_run failed");
        let resumed = mp_bcfw::resume(&fresh, &mut eng, &full_cfg, &mut reloaded);
        std::fs::remove_file(&path).ok();

        // The resumed series covers outers 5..=8; it must equal the
        // tail of the uninterrupted series bit for bit (values and the
        // oracle-call ledger; timing columns restart and are excluded).
        let resumed_bits = bits(&resumed);
        assert_eq!(
            resumed_bits.len(),
            4,
            "{products:?}: expected points for outers 5..=8, got {resumed_bits:?}"
        );
        let full_tail: Vec<_> =
            bits(&full).into_iter().filter(|&(outer, ..)| outer >= 5).collect();
        assert_eq!(
            resumed_bits, full_tail,
            "{products:?}: resumed trajectory diverged from the uninterrupted run"
        );
    }
}

#[test]
fn foreign_file_is_rejected_naming_the_magic_offset() {
    let path = tmp("foreign");
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(b"definitely not a run checkpoint, long enough to read").unwrap();
    drop(f);
    let problem = tiny_problem();
    let err = load_run(&path, &problem, &cfg(8, ProductMode::Incremental))
        .expect_err("foreign bytes must not load");
    std::fs::remove_file(&path).ok();
    let msg = err.to_string();
    assert!(msg.contains("bad magic"), "unhelpful error: {msg}");
    assert!(msg.contains("byte offset 8"), "error must name the offset: {msg}");
}

#[test]
fn truncated_checkpoint_is_rejected_naming_the_failing_offset() {
    let full_cfg = cfg(4, ProductMode::Incremental);
    let problem = tiny_problem();
    let mut eng = NativeEngine;
    let (_, run) = mp_bcfw::run(&problem, &mut eng, &full_cfg);
    let path = tmp("truncated");
    save_run(&path, &run, &problem).expect("save_run failed");

    let bytes = std::fs::read(&path).unwrap();
    let cut = bytes.len() / 2;
    std::fs::write(&path, &bytes[..cut]).unwrap();

    let fresh = tiny_problem();
    let err = load_run(&path, &fresh, &full_cfg).expect_err("truncated file must not load");
    std::fs::remove_file(&path).ok();
    let msg = err.to_string();
    assert!(
        msg.contains("byte offset"),
        "truncation error must name where the read failed: {msg}"
    );
}

#[test]
fn averaged_runs_refuse_to_load() {
    let base = cfg(4, ProductMode::Incremental);
    let problem = tiny_problem();
    let mut eng = NativeEngine;
    let (_, run) = mp_bcfw::run(&problem, &mut eng, &base);
    let path = tmp("averaged");
    save_run(&path, &run, &problem).expect("save_run failed");

    let avg_cfg = MpBcfwConfig { averaging: true, ..base };
    let err = load_run(&path, &problem, &avg_cfg).expect_err("averaging must be refused");
    std::fs::remove_file(&path).ok();
    assert!(err.to_string().contains("averager"), "unhelpful error: {err}");
}

//! Fault-tolerance conformance: the deterministic-injection contract of
//! `--faults inject` end to end. The fault schedule is a pure function
//! of `(fault seed, block, pass, attempt)`, so everything here is
//! driven without wall-clock dependence: the [`VirtualExecutor`] replays
//! adversarial completion orders under injected faults, twin runs with
//! the same fault seed must match bitwise, the sharded synchronous
//! driver must be thread-count-invariant even while workers fail, and a
//! run killed at an auto-checkpoint must resume onto the uninterrupted
//! run's eval tail bit for bit.
//!
//! The `--faults off` anchor is pinned elsewhere: the golden-trajectory
//! fixtures replay default (`FaultMode::Off`) specs, so any off-path
//! perturbation from this PR would trip `tests/golden_trajectory.rs`.

use std::sync::Arc;

use mpbcfw::coordinator::async_overlap::{
    run_async_with, AsyncMode, CompletionOrder, VirtualExecutor,
};
use mpbcfw::coordinator::checkpoint::{load_run, save_run_atomic};
use mpbcfw::coordinator::faults::{FaultConfig, FaultKind, FaultMode, FaultPlan};
use mpbcfw::coordinator::metrics::Series;
use mpbcfw::coordinator::mp_bcfw::{self, MpBcfwConfig};
use mpbcfw::coordinator::parallel::{exact_pass, exact_pass_faulty};
use mpbcfw::data::synth::usps_like::{generate, UspsLikeConfig};
use mpbcfw::data::types::Scale;
use mpbcfw::model::problem::StructuredProblem as _;
use mpbcfw::model::scratch::OracleScratch;
use mpbcfw::oracle::multiclass::MulticlassProblem;
use mpbcfw::oracle::wrappers::CountingOracle;
use mpbcfw::runtime::engine::NativeEngine;

fn tiny_problem() -> CountingOracle {
    CountingOracle::new(Box::new(MulticlassProblem::new(generate(
        UspsLikeConfig::at_scale(Scale::Tiny),
        1,
    ))))
}

/// Pinned base config: `auto_approx` off (the §3.4 rule is
/// wall-clock-driven and would fork twin trajectories) and a fixed
/// approximate-pass budget, as in the async and checkpoint suites.
fn base_cfg(max_iters: u64) -> MpBcfwConfig {
    MpBcfwConfig {
        max_iters,
        auto_approx: false,
        max_approx_passes: 2,
        threads: 2,
        seed: 7,
        ..MpBcfwConfig::mp_paper(1.0 / 60.0)
    }
}

fn inject_cfg(max_iters: u64, fault_seed: u64, rate: f64, retries: u64) -> MpBcfwConfig {
    MpBcfwConfig {
        faults: FaultConfig {
            mode: FaultMode::Inject,
            seed: fault_seed,
            rate,
            retries,
            timeout_s: 0.5,
            ..FaultConfig::default()
        },
        ..base_cfg(max_iters)
    }
}

/// Trajectory identity: (outer, dual bits, primal bits, exact-oracle
/// calls) per evaluation point. Timing columns are wall-clock-derived
/// and excluded.
fn bits(s: &Series) -> Vec<(u64, u64, u64, u64)> {
    s.points
        .iter()
        .map(|p| (p.outer, p.dual.to_bits(), p.primal.to_bits(), p.oracle_calls))
        .collect()
}

fn assert_monotone_and_weakly_dual(s: &Series, label: &str) {
    for p in &s.points {
        assert!(p.primal >= p.dual - 1e-8, "{label}: weak duality violated at {p:?}");
    }
    for w in s.points.windows(2) {
        assert!(
            w[1].dual >= w[0].dual - 1e-10,
            "{label}: dual decreased {} -> {} under injection",
            w[0].dual,
            w[1].dual
        );
    }
}

/// Run the async driver against a fault-injecting [`VirtualExecutor`]
/// with the given completion order; returns the series and the shared
/// fault plan (for its counters).
fn faulty_async_series(
    cfg: &MpBcfwConfig,
    order: CompletionOrder,
) -> (Series, Arc<FaultPlan>) {
    let problem = tiny_problem();
    let mut eng = NativeEngine;
    let c = MpBcfwConfig { async_mode: AsyncMode::On, max_stale_epochs: 1, ..cfg.clone() };
    let plan = Arc::new(FaultPlan::from_config(&c.faults));
    let mut exec = VirtualExecutor::with_faults(
        &problem,
        c.threads,
        c.oracle_reuse,
        order,
        Arc::clone(&plan),
    );
    let (series, _) = run_async_with(&problem, &mut eng, &c, &mut exec);
    (series, plan)
}

#[test]
fn fault_matrix_stays_monotone_and_convergent_under_adversarial_orders() {
    // Clean reference: the synchronous fault-free driver.
    let problem = tiny_problem();
    let mut eng = NativeEngine;
    let (clean, _) = mp_bcfw::run(&problem, &mut eng, &base_cfg(6));
    let clean_dual = clean.points.last().unwrap().dual;
    assert!(clean_dual > 0.0, "clean reference made no progress");

    let cfg = inject_cfg(6, 11, 0.7, 1);
    // Every fault kind is on the pure schedule for this (seed, rate)
    // over the swept (block, pass, attempt) grid — so the matrix below
    // genuinely exercises each kind under each completion order.
    let plan = FaultPlan::from_config(&cfg.faults);
    for kind in [FaultKind::Panic, FaultKind::Transient, FaultKind::Timeout, FaultKind::Slow] {
        let scheduled = (0..60usize).any(|b| {
            (1..=6u64).any(|pass| (0..=1u64).any(|a| plan.decide(b, pass, a) == Some(kind)))
        });
        assert!(scheduled, "{kind:?} never appears on the schedule; pick another seed");
    }

    let mut totals = mpbcfw::coordinator::faults::FaultStats::default();
    for order in
        [CompletionOrder::Fifo, CompletionOrder::Reversed, CompletionOrder::Starve(0)]
    {
        let (s, plan) = faulty_async_series(&cfg, order);
        assert_eq!(s.faults, "inject");
        assert_monotone_and_weakly_dual(&s, &format!("{order:?}"));
        // Bounded-extra-passes convergence: injection may cost progress
        // (skipped blocks, degraded passes) but not collapse the run.
        let last = s.points.last().unwrap();
        assert!(
            last.dual >= 0.25 * clean_dual,
            "{order:?}: faulty dual {} lost the clean reference {clean_dual}",
            last.dual
        );
        let st = plan.stats();
        assert!(st.injected > 0, "{order:?}: nothing was injected");
        assert_eq!(
            st.panics + st.transients + st.timeouts + st.slowdowns,
            st.injected,
            "{order:?}: per-kind counters must partition the injections"
        );
        // The EvalPoint columns surface the same counters.
        assert_eq!(last.oracle_retries, st.retries, "{order:?}: retries column");
        assert_eq!(last.oracle_timeouts, st.timeouts, "{order:?}: timeouts column");
        totals.injected += st.injected;
        totals.panics += st.panics;
        totals.transients += st.transients;
        totals.timeouts += st.timeouts;
        totals.slowdowns += st.slowdowns;
    }
    // Across the three orders, every fault kind was actually executed.
    assert!(totals.panics > 0, "no panic was ever executed");
    assert!(totals.transients > 0, "no transient error was ever executed");
    assert!(totals.timeouts > 0, "no timeout was ever executed");
    assert!(totals.slowdowns > 0, "no slowdown was ever executed");
}

#[test]
fn same_fault_seed_twins_are_bitwise_identical() {
    let cfg = inject_cfg(5, 23, 0.6, 1);
    for order in
        [CompletionOrder::Fifo, CompletionOrder::Reversed, CompletionOrder::Starve(1)]
    {
        let (a, plan_a) = faulty_async_series(&cfg, order);
        let (b, plan_b) = faulty_async_series(&cfg, order);
        assert_eq!(bits(&a), bits(&b), "{order:?}: same-fault-seed twins diverged");
        assert_eq!(
            plan_a.stats(),
            plan_b.stats(),
            "{order:?}: twins drew different fault schedules"
        );
        assert!(plan_a.stats().injected > 0, "{order:?}: twin check never injected");
    }
    // A different fault seed must fork the schedule (the seed is live).
    let (c, plan_c) = faulty_async_series(&inject_cfg(5, 24, 0.6, 1), CompletionOrder::Fifo);
    let (a, _) = faulty_async_series(&cfg, CompletionOrder::Fifo);
    assert!(plan_c.stats().injected > 0);
    assert_ne!(bits(&a), bits(&c), "changing --fault-seed moved nothing");
}

#[test]
fn sync_injection_is_thread_count_invariant() {
    // The fault schedule is pure in (block, pass, attempt) — never in
    // the worker id — and blocks map to arenas by id % m, so the sharded
    // synchronous driver must produce one bitwise trajectory for every
    // thread count, faults and all. This is the reassignment invariant:
    // a failed block requeues into the same residue class.
    let mut reference: Option<Vec<(u64, u64, u64, u64)>> = None;
    for threads in [1usize, 2, 3] {
        let problem = tiny_problem();
        let mut eng = NativeEngine;
        let cfg = MpBcfwConfig { threads, ..inject_cfg(6, 31, 0.5, 1) };
        let (s, run) = mp_bcfw::run(&problem, &mut eng, &cfg);
        assert_monotone_and_weakly_dual(&s, &format!("threads={threads}"));
        assert!(run.faults.stats().injected > 0, "threads={threads}: nothing injected");
        match &reference {
            None => reference = Some(bits(&s)),
            Some(want) => assert_eq!(
                &bits(&s),
                want,
                "threads={threads} diverged: injection broke thread-count invariance"
            ),
        }
    }
}

#[test]
fn worker_death_recovery_preserves_arena_pinning() {
    // Pass 1 injects heavily (retry budget 0), pass 2 is healed (the
    // fault window closes). Re-running the failed blocks on the *same*
    // persistent arenas must produce planes bitwise identical to a cold
    // single-threaded reference: the id % m pinning survives both the
    // failures and any arena cold-resets.
    let problem = tiny_problem();
    let w = vec![0.0; problem.dim()];
    let order: Vec<usize> = (0..problem.n()).collect();
    let plan = FaultPlan::from_config(&FaultConfig {
        mode: FaultMode::Inject,
        seed: 5,
        rate: 0.9,
        retries: 0,
        window: Some((1, 2)), // pass 1 faulty, pass 2 healed
        ..FaultConfig::default()
    });
    let mut arenas: Vec<OracleScratch> = (0..3).map(|_| OracleScratch::cold()).collect();
    let (first, _) = exact_pass_faulty(&problem, &w, &order, 3, &mut arenas, &plan, 1);
    let failed: Vec<usize> = order
        .iter()
        .zip(&first)
        .filter(|(_, p)| p.is_none())
        .map(|(&b, _)| b)
        .collect();
    assert!(!failed.is_empty(), "heavy pass failed no block; raise the rate");
    assert!(failed.len() < order.len(), "every block failed; Slow should pass some");

    // Healed retry pass over the failed blocks, warm arenas.
    let (second, _) = exact_pass_faulty(&problem, &w, &failed, 3, &mut arenas, &plan, 2);
    let (want, _) = exact_pass(&problem, &w, &failed, 1);
    for ((&b, got), clean) in failed.iter().zip(&second).zip(&want) {
        let got = got.as_ref().expect("healed pass must not fail");
        assert_eq!(got.tag, clean.tag, "block {b}: retry plane diverged");
        assert_eq!(got.off, clean.off, "block {b}: retry offset diverged");
    }
}

#[test]
fn kill_at_checkpoint_then_resume_matches_the_uninterrupted_tail() {
    let full_cfg = inject_cfg(8, 17, 0.4, 1);

    // Reference: one uninterrupted faulty run.
    let reference = tiny_problem();
    let mut eng = NativeEngine;
    let (full, _) = mp_bcfw::run(&reference, &mut eng, &full_cfg);
    assert_monotone_and_weakly_dual(&full, "uninterrupted");

    // "Killed" run: same schedule, auto-checkpointing every 2 outers,
    // stopped at 4 — the last atomic write stands in for the kill point.
    let path = std::env::temp_dir()
        .join(format!("mpbcfw_it_fault_resume_{}", std::process::id()));
    let killed_cfg = MpBcfwConfig {
        max_iters: 4,
        faults: FaultConfig {
            checkpoint_every: 2,
            checkpoint_path: path.to_string_lossy().into_owned(),
            ..full_cfg.faults.clone()
        },
        ..full_cfg.clone()
    };
    let problem = tiny_problem();
    let (killed, _) = mp_bcfw::run(&problem, &mut eng, &killed_cfg);
    assert!(path.is_file(), "auto-checkpoint never written");
    // Auto-checkpointing is trajectory-neutral: the killed run's points
    // are the uninterrupted run's head, bit for bit.
    let full_bits = bits(&full);
    assert_eq!(bits(&killed), full_bits[..bits(&killed).len()].to_vec());

    // Resume from the checkpoint in a fresh problem (fresh caches,
    // fresh arenas) under the original full config.
    let fresh = tiny_problem();
    let mut reloaded = load_run(&path, &fresh, &full_cfg).expect("load_run failed");
    assert_eq!(reloaded.outers_done, 4);
    let resumed = mp_bcfw::resume(&fresh, &mut eng, &full_cfg, &mut reloaded);
    std::fs::remove_file(&path).ok();

    let resumed_bits = bits(&resumed);
    let full_tail: Vec<_> =
        full_bits.into_iter().filter(|&(outer, ..)| outer >= 5).collect();
    assert_eq!(
        resumed_bits, full_tail,
        "resumed faulty run diverged from the uninterrupted eval tail"
    );
}

#[test]
fn atomic_checkpoints_never_leave_tmp_residue() {
    let problem = tiny_problem();
    let mut eng = NativeEngine;
    let (_, run) = mp_bcfw::run(&problem, &mut eng, &base_cfg(3));
    let path = std::env::temp_dir()
        .join(format!("mpbcfw_it_fault_atomic_{}", std::process::id()));
    save_run_atomic(&path, &run, &problem).expect("atomic save failed");
    save_run_atomic(&path, &run, &problem).expect("atomic overwrite failed");
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    assert!(
        !std::path::Path::new(&tmp).exists(),
        "tmp file left behind by the atomic rename"
    );
    let back = load_run(&path, &problem, &base_cfg(3)).expect("atomic file unreadable");
    assert_eq!(back.outers_done, run.outers_done);
    std::fs::remove_file(&path).ok();
}

//! Engine parity: the PJRT-backed XlaEngine must reproduce the native
//! engine's numbers (f32 tolerance) on raw ops and on full training runs,
//! with zero native fallbacks for every shipped dataset shape.
//!
//! These tests are skipped (not failed) when `artifacts/` has not been
//! built — run `make artifacts` first.

#![cfg(feature = "xla-rt")]

use mpbcfw::coordinator::trainer::{self, Algo, EngineKind, TrainSpec};
use mpbcfw::data::types::Scale;
use mpbcfw::runtime::engine::{NativeEngine, ScoringEngine};
use mpbcfw::runtime::xla::XlaEngine;
use mpbcfw::utils::math::rel_diff;
use mpbcfw::utils::rng::Pcg;

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(dir).join("manifest.json").exists() {
        Some(dir.to_string())
    } else {
        eprintln!("skipping: artifacts/ not built");
        None
    }
}

#[test]
fn matvec_parity_across_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaEngine::load(&dir).unwrap();
    let mut native = NativeEngine;
    let mut rng = Pcg::seeded(1);
    for (rows, cols) in
        [(1, 10), (10, 161), (7, 641), (50, 2561), (3, 85), (200, 1299), (1000, 4005)]
    {
        let mat: Vec<f64> = (0..rows * cols).map(|_| rng.normal()).collect();
        let v: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
        let mut a = Vec::new();
        let mut b = Vec::new();
        xla.matvec(&mat, rows, cols, &v, &mut a);
        native.matvec(&mat, rows, cols, &v, &mut b);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert!(rel_diff(*x, *y) < 5e-4, "({rows},{cols}): {x} vs {y}");
        }
    }
    assert_eq!(xla.stats.fallbacks, 0, "all shapes must hit an artifact bucket");
    assert!(xla.stats.calls >= 7);
}

#[test]
fn matmul_bt_parity_across_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaEngine::load(&dir).unwrap();
    let mut native = NativeEngine;
    let mut rng = Pcg::seeded(2);
    for (m, k, n) in
        [(5, 8, 6), (11, 32, 26), (8, 128, 26), (36, 12, 2), (144, 64, 2), (289, 649, 2)]
    {
        let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
        let mut x = Vec::new();
        let mut y = Vec::new();
        xla.matmul_bt(&a, m, k, &b, n, &mut x);
        native.matmul_bt(&a, m, k, &b, n, &mut y);
        assert_eq!(x.len(), y.len());
        for (p, q) in x.iter().zip(&y) {
            assert!(rel_diff(*p, *q) < 5e-4, "({m},{k},{n}): {p} vs {q}");
        }
    }
    assert_eq!(xla.stats.fallbacks, 0);
}

#[test]
fn unknown_shape_falls_back_to_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaEngine::load(&dir).unwrap();
    let mut rng = Pcg::seeded(3);
    let rows = 4096; // beyond every bucket
    let mat: Vec<f64> = (0..rows * 2).map(|_| rng.normal()).collect();
    let v: Vec<f64> = (0..2).map(|_| rng.normal()).collect();
    let mut out = Vec::new();
    xla.matvec(&mat, rows, 2, &v, &mut out);
    assert_eq!(out.len(), rows);
    assert!(xla.stats.fallbacks >= 1);
}

#[test]
fn executables_are_memoized() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaEngine::load(&dir).unwrap();
    let mat = vec![1.0; 10 * 161];
    let v = vec![0.5; 161];
    let mut out = Vec::new();
    xla.matvec(&mat, 10, 161, &v, &mut out);
    let compiles_after_first = xla.stats.compiles;
    for _ in 0..5 {
        xla.matvec(&mat, 10, 161, &v, &mut out);
    }
    assert_eq!(xla.stats.compiles, compiles_after_first, "recompiled a cached bucket");
    assert_eq!(xla.stats.calls, 6);
}

#[test]
fn training_run_parity_native_vs_xla() {
    let Some(dir) = artifacts_dir() else { return };
    // Full MP-BCFW run on each tiny dataset under both engines: identical
    // oracle decisions should produce near-identical convergence traces.
    for dataset in trainer::DatasetKind::all() {
        let mk_spec = |engine| TrainSpec {
            dataset,
            scale: Scale::Tiny,
            algo: Algo::MpBcfw,
            max_iters: 8,
            engine,
            ..Default::default()
        };
        let s_native = trainer::train(&mk_spec(EngineKind::Native)).unwrap();
        let s_xla =
            trainer::train(&mk_spec(EngineKind::Xla { artifacts_dir: dir.clone() })).unwrap();
        assert_eq!(s_native.points.len(), s_xla.points.len());
        // Early points must match tightly (trajectories start identical);
        // later points may diverge when f32 rounding flips a near-tied
        // argmax — both trajectories are then valid optimizer paths — so
        // for the run as a whole we require matching *convergence
        // quality*, not bitwise-equal paths.
        let (a0, b0) = (&s_native.points[1], &s_xla.points[1]);
        assert!(
            rel_diff(a0.dual, b0.dual) < 2e-3,
            "{dataset:?}: first-pass dual {} vs {}",
            a0.dual,
            b0.dual
        );
        let (an, bn) = (s_native.points.last().unwrap(), s_xla.points.last().unwrap());
        assert_eq!(an.oracle_calls, bn.oracle_calls);
        assert!(
            rel_diff(an.dual, bn.dual) < 0.05,
            "{dataset:?}: final dual {} vs {}",
            an.dual,
            bn.dual
        );
        // Both engines must make comparable *progress* — the gap shrinks
        // to a small fraction of its initial value — rather than follow
        // equal paths (see note above).
        let gap0 = s_native.points[0].primal - s_native.points[0].dual;
        let (gap_a, gap_b) = (an.primal - an.dual, bn.primal - bn.dual);
        assert!(
            gap_a < 0.2 * gap0 && gap_b < 0.2 * gap0,
            "{dataset:?}: gaps {gap_a} (native) / {gap_b} (xla) vs initial {gap0}"
        );
        for p in &s_xla.points {
            assert!(p.primal >= p.dual - 1e-6, "{dataset:?}: weak duality under xla engine");
        }
    }
}

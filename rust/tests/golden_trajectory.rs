//! Golden-trajectory conformance: committed fixtures of the first K
//! passes' dual values (hex-encoded f64 bits) replayed bitwise, per
//! scenario × config family. This promotes the A/B discipline of the
//! bench tables from "same-process twin runs" to "pinned across PRs" —
//! any change that silently perturbs a trajectory fails here naming the
//! first diverging pass.
//!
//! Fixtures under `tests/fixtures/golden/` carry a `pinned` flag with
//! the same bootstrap semantics as the `BENCH_*.json` baselines: an
//! unpinned fixture has no trusted duals yet, so the test gates twin-run
//! determinism and monotonicity only. To pin (or intentionally re-pin
//! after a wanted trajectory change), run with `GOLDEN_BLESS=1` and
//! commit the rewritten fixtures like code:
//!
//! ```text
//! GOLDEN_BLESS=1 cargo test --test golden_trajectory
//! ```

use std::path::{Path, PathBuf};

use mpbcfw::bench::regress::{f64_of_hex, hex_of};
use mpbcfw::coordinator::products::{GramBackend, ProductMode};
use mpbcfw::coordinator::trainer::{train, Algo, DatasetKind, TrainSpec};
use mpbcfw::data::types::Scale;
use mpbcfw::utils::json::Json;

/// One committed golden-trajectory fixture. Checked for struct-literal
/// exhaustiveness by `tools/desk_check.py`.
pub struct GoldenFixture {
    pub schema_version: u64,
    pub scenario: String,
    pub dataset: String,
    /// Config family: "default" (incremental products, triangular Gram)
    /// or "recompute" (paper-literal recompute + hashmap Gram). The two
    /// must follow the *same* dual trajectory, but each pins its own
    /// fixture so a divergence names the family that moved.
    pub family: String,
    /// False until blessed: duals_hex is untrusted and only twin-run
    /// determinism is gated (see the module docs).
    pub pinned: bool,
    pub seed: u64,
    pub data_seed: u64,
    /// Outer passes replayed; the trajectory has `passes + 1` points
    /// (the pass-0 evaluation included).
    pub passes: u64,
    pub duals_hex: Vec<String>,
}

impl GoldenFixture {
    fn from_json(j: &Json) -> Result<GoldenFixture, String> {
        let req = |key: &str| -> Result<f64, String> {
            j.get(key).as_f64().ok_or_else(|| format!("missing/non-numeric '{key}'"))
        };
        let req_s = |key: &str| -> Result<String, String> {
            j.get(key)
                .as_str()
                .map(String::from)
                .ok_or_else(|| format!("missing/non-string '{key}'"))
        };
        let pinned = match j.get("pinned") {
            Json::Bool(b) => *b,
            _ => return Err("missing/non-bool 'pinned'".into()),
        };
        let duals_hex = j
            .get("duals_hex")
            .as_arr()
            .ok_or("missing 'duals_hex'")?
            .iter()
            .map(|v| v.as_str().map(String::from).ok_or("non-string dual hex".to_string()))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(GoldenFixture {
            schema_version: req("schema_version")? as u64,
            scenario: req_s("scenario")?,
            dataset: req_s("dataset")?,
            family: req_s("family")?,
            pinned,
            seed: req("seed")? as u64,
            data_seed: req("data_seed")? as u64,
            passes: req("passes")? as u64,
            duals_hex,
        })
    }

    fn load(path: &Path) -> Result<GoldenFixture, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        GoldenFixture::from_json(&Json::parse(&text)?)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Num(self.schema_version as f64)),
            ("scenario", Json::s(&self.scenario)),
            ("dataset", Json::s(&self.dataset)),
            ("family", Json::s(&self.family)),
            ("pinned", Json::Bool(self.pinned)),
            ("seed", Json::Num(self.seed as f64)),
            ("data_seed", Json::Num(self.data_seed as f64)),
            ("passes", Json::Num(self.passes as f64)),
            ("duals_hex", Json::arr(self.duals_hex.iter().map(|h| Json::s(h)))),
        ])
    }
}

fn fixture_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/golden"))
}

const FIXTURES: &[&str] = &[
    "golden_usps_like_default.json",
    "golden_usps_like_recompute.json",
    "golden_ocr_like_default.json",
    "golden_ocr_like_recompute.json",
    "golden_horseseg_like_default.json",
    "golden_horseseg_like_recompute.json",
];

/// The replay spec a fixture pins. `auto_approx` must stay off — the
/// §3.4 rule is wall-clock-driven and would fork the trajectory on a
/// machine of different speed.
fn spec_for(f: &GoldenFixture) -> TrainSpec {
    let (products, gram) = match f.family.as_str() {
        "recompute" => (ProductMode::Recompute, GramBackend::Hashmap),
        _ => (ProductMode::Incremental, GramBackend::Triangular),
    };
    TrainSpec {
        dataset: DatasetKind::parse(&f.dataset).expect("fixture dataset"),
        scale: Scale::Tiny,
        data_seed: f.data_seed,
        algo: Algo::MpBcfw,
        seed: f.seed,
        max_iters: f.passes,
        auto_approx: false,
        max_approx_passes: 3,
        products,
        gram,
        eval_every: 1,
        ..Default::default()
    }
}

fn run_duals(spec: &TrainSpec) -> Vec<f64> {
    train(spec).unwrap().points.iter().map(|p| p.dual).collect()
}

fn bless(path: &Path, f: &GoldenFixture, hexes: &[String]) {
    let pinned = GoldenFixture {
        schema_version: f.schema_version,
        scenario: f.scenario.clone(),
        dataset: f.dataset.clone(),
        family: f.family.clone(),
        pinned: true,
        seed: f.seed,
        data_seed: f.data_seed,
        passes: f.passes,
        duals_hex: hexes.to_vec(),
    };
    let mut text = pinned.to_json().to_string();
    text.push('\n');
    std::fs::write(path, text).unwrap();
    eprintln!("blessed {}", path.display());
}

#[test]
fn golden_trajectories_replay_bitwise() {
    for name in FIXTURES {
        let path = fixture_dir().join(name);
        let f = GoldenFixture::load(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(f.schema_version, 1, "{name}: unknown fixture schema");
        assert!(
            name.contains(&f.dataset) && name.contains(&f.family),
            "{name}: dataset/family fields ({}, {}) disagree with the filename",
            f.dataset,
            f.family
        );
        let duals = run_duals(&spec_for(&f));
        assert_eq!(duals.len() as u64, f.passes + 1, "{name}: eval point count");
        // Monotone non-decreasing dual, pinned or not (house tolerance
        // for evaluation rounding, as in the convergence suite).
        for (i, w) in duals.windows(2).enumerate() {
            assert!(
                w[1] >= w[0] - 1e-10,
                "{name}: dual decreased at pass {}: {} -> {}",
                i,
                w[0],
                w[1]
            );
        }
        let hexes: Vec<String> = duals.iter().map(|&d| hex_of(d)).collect();
        if f.pinned {
            assert_eq!(
                hexes.len(),
                f.duals_hex.len(),
                "{name}: trajectory length changed — rebless intentionally"
            );
            for (i, (got, want)) in hexes.iter().zip(&f.duals_hex).enumerate() {
                assert_eq!(
                    got,
                    want,
                    "{name}: dual diverged at pass {i}: committed {} ({:?}), got {} ({:?}) \
                     — a real regression, or rebless with GOLDEN_BLESS=1 if intended",
                    want,
                    f64_of_hex(want),
                    got,
                    duals[i]
                );
            }
        } else {
            // Bootstrap fixture (authored without a toolchain): gate
            // what is checkable without history — a twin run replays
            // bitwise — and allow pinning via GOLDEN_BLESS=1.
            let twin: Vec<String> =
                run_duals(&spec_for(&f)).iter().map(|&d| hex_of(d)).collect();
            assert_eq!(hexes, twin, "{name}: twin run diverged — trajectory nondeterministic");
            if std::env::var("GOLDEN_BLESS").ok().as_deref() == Some("1") {
                bless(&path, &f, &hexes);
            }
        }
    }
}

#[test]
fn default_and_recompute_families_share_one_trajectory() {
    // The §3.5 incremental product path is an exact serving-layer
    // optimization: same steps, same duals as paper-literal recompute.
    // The per-family fixtures pin this across PRs; here it must hold
    // within one build too.
    for ds in [DatasetKind::UspsLike, DatasetKind::OcrLike, DatasetKind::HorsesegLike] {
        let mk = |family: &str| GoldenFixture {
            schema_version: 1,
            scenario: String::new(),
            dataset: ds.name().to_string(),
            family: family.to_string(),
            pinned: false,
            seed: 0,
            data_seed: 0,
            passes: 4,
            duals_hex: Vec::new(),
        };
        let a = run_duals(&spec_for(&mk("default")));
        let b = run_duals(&spec_for(&mk("recompute")));
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&b), "{}: families diverged", ds.name());
    }
}

//! Integration contract of the gap-aware sampling subsystem (ISSUE 2):
//!
//!  1. seeded **uniform** trajectories are bit-identical to the
//!     pre-sampling code (pinned via `bcfw::run_reference`, the
//!     untouched Algorithm-2 transcription, and via the sampler/RNG
//!     stream equivalence);
//!  2. **gap-proportional** sampling reaches a fixed duality gap on
//!     `horseseg_like` within the uniform run's exact-oracle budget;
//!  3. **pairwise** steps never decrease the dual and conserve the
//!     convex-coefficient ledgers.

use mpbcfw::coordinator::bcfw;
use mpbcfw::coordinator::mp_bcfw::{self, MpBcfwConfig};
use mpbcfw::coordinator::sampling::{
    build_sampler, BlockGaps, BlockSampler as _, SamplingStrategy, StepRule,
};
use mpbcfw::coordinator::trainer::{self, Algo, DatasetKind, TrainSpec};
use mpbcfw::data::synth::usps_like::{generate, UspsLikeConfig};
use mpbcfw::data::types::Scale;
use mpbcfw::model::problem::StructuredProblem;
use mpbcfw::oracle::multiclass::MulticlassProblem;
use mpbcfw::oracle::wrappers::CountingOracle;
use mpbcfw::runtime::engine::NativeEngine;
use mpbcfw::utils::rng::Pcg;

fn usps_tiny(seed: u64) -> CountingOracle {
    CountingOracle::new(Box::new(MulticlassProblem::new(generate(
        UspsLikeConfig::at_scale(Scale::Tiny),
        seed,
    ))))
}

/// The uniform sampler consumes exactly the permutation stream the
/// pre-PR exact pass consumed — same RNG constructor, same draws.
#[test]
fn uniform_sampler_equals_pre_pr_permutation_stream() {
    let n = 60;
    let gaps = BlockGaps::new(n);
    let mut sampler = build_sampler(SamplingStrategy::Uniform, n);
    // mp_bcfw::run seeds its pass RNG as Pcg::new(seed, 7001).
    let mut sampler_rng = Pcg::new(42, 7001);
    let mut raw_rng = Pcg::new(42, 7001);
    for _ in 0..10 {
        assert_eq!(sampler.pass_order(&mut sampler_rng, &gaps), raw_rng.permutation(n));
    }
}

/// Uniform-sampling MP-BCFW in the N = M = 0 configuration must still be
/// bit-identical to the standalone Algorithm-2 reference (which predates
/// and does not use the sampling subsystem): same permutation stream,
/// same arithmetic, equal floats — the pre-PR trajectory anchor.
#[test]
fn uniform_trajectory_bit_identical_to_pre_pr_reference() {
    let mut eng = NativeEngine;
    let lambda = 1.0 / 60.0;
    let passes = 6;
    let p1 = usps_tiny(1);
    let ref_state = bcfw::run_reference(&p1, &mut eng, lambda, passes, 5);
    let p2 = usps_tiny(1);
    let cfg = MpBcfwConfig {
        max_iters: passes,
        seed: 5,
        eval_every: passes,
        sampling: SamplingStrategy::Uniform,
        ..MpBcfwConfig::bcfw(lambda)
    };
    let (_, run) = mp_bcfw::run(&p2, &mut eng, &cfg);
    assert_eq!(ref_state.dual_value(), run.state.dual_value());
    assert_eq!(ref_state.phi.off, run.state.phi.off);
    for (a, b) in ref_state.phi.star.iter().zip(&run.state.phi.star) {
        assert_eq!(a, b, "uniform trajectory diverged from the pre-PR anchor");
    }
}

/// Two runs of the full MP configuration at the same seed agree exactly
/// (the gap bookkeeping is deterministic and purely read-only for the
/// uniform trajectory).
#[test]
fn uniform_full_mp_run_is_reproducible() {
    let mut eng = NativeEngine;
    let cfg = MpBcfwConfig {
        max_iters: 5,
        seed: 9,
        auto_approx: false,
        max_approx_passes: 3,
        ..MpBcfwConfig::mp_paper(0.02)
    };
    let (s1, _) = mp_bcfw::run(&usps_tiny(1), &mut eng, &cfg);
    let (s2, _) = mp_bcfw::run(&usps_tiny(1), &mut eng, &cfg);
    for (a, b) in s1.points.iter().zip(&s2.points) {
        assert_eq!(a.dual, b.dual);
        assert_eq!(a.primal, b.primal);
        assert_eq!(a.oracle_calls, b.oracle_calls);
    }
}

/// The headline claim on the costly-oracle dataset: gap-proportional
/// sampling reaches the duality gap the uniform run ends at using no
/// more exact-oracle calls (ISSUE 2 acceptance criterion).
#[test]
fn gap_sampling_reaches_target_within_uniform_budget_on_horseseg() {
    let iters = 10;
    let base = TrainSpec {
        dataset: DatasetKind::HorsesegLike,
        scale: Scale::Tiny,
        algo: Algo::MpBcfw,
        max_iters: iters,
        seed: 0,
        ..Default::default()
    };
    let uniform = trainer::train(&base).unwrap();
    let u_last = uniform.points.last().unwrap();
    let target = (u_last.primal - u_last.dual).max(1e-12);
    let u_calls = u_last.oracle_calls;

    let gap_spec = TrainSpec {
        sampling: SamplingStrategy::GapProportional,
        target_gap: target,
        max_iters: iters * 4,
        max_oracle_calls: u_calls * 4,
        ..base
    };
    let gap_series = trainer::train(&gap_spec).unwrap();
    let hit = gap_series
        .points
        .iter()
        .find(|p| p.primal - p.dual <= target)
        .unwrap_or_else(|| panic!("gap sampling never reached target {target}"));
    assert!(
        hit.oracle_calls <= u_calls,
        "gap sampling took {} exact calls to gap {target:.3e}; uniform budget is {u_calls}",
        hit.oracle_calls
    );
}

/// Pairwise steps carry an exact line search along an ascent direction,
/// so the dual is monotone; ledgers conserve unit mass; weak duality and
/// the φ = Σφ^i invariant hold at the end.
#[test]
fn pairwise_steps_never_decrease_the_dual() {
    let mut eng = NativeEngine;
    for seed in [0u64, 3] {
        let problem = usps_tiny(seed + 1);
        let cfg = MpBcfwConfig {
            max_iters: 10,
            seed,
            steps: StepRule::Pairwise,
            ..MpBcfwConfig::mp_paper(1.0 / 60.0)
        };
        let (series, run) = mp_bcfw::run(&problem, &mut eng, &cfg);
        for w in series.points.windows(2) {
            assert!(
                w[1].dual >= w[0].dual - 1e-10,
                "dual decreased under pairwise steps: {:?} -> {:?}",
                w[0].dual,
                w[1].dual
            );
        }
        let last = series.points.last().unwrap();
        assert!(last.primal >= last.dual - 1e-9, "weak duality violated");
        assert!(run.pairwise_steps_total > 0, "no pairwise transfer fired");
        for co in &run.coeffs {
            assert!((co.total() - 1.0).abs() < 1e-6, "ledger mass {}", co.total());
        }
        assert!(run.state.consistency_error() < 1e-6);
    }
}

/// Pairwise + gap sampling composes, and on the graph-cut dataset the
/// combination still satisfies the dual-monotonicity contract.
#[test]
fn gap_sampling_with_pairwise_steps_on_horseseg() {
    let spec = TrainSpec {
        dataset: DatasetKind::HorsesegLike,
        scale: Scale::Tiny,
        algo: Algo::MpBcfw,
        max_iters: 6,
        sampling: SamplingStrategy::GapProportional,
        steps: StepRule::Pairwise,
        ..Default::default()
    };
    let series = trainer::train(&spec).unwrap();
    for w in series.points.windows(2) {
        assert!(w[1].dual >= w[0].dual - 1e-10);
    }
    let last = series.points.last().unwrap();
    assert!(last.gap_est.is_finite() && last.gap_est >= 0.0);
    assert_eq!(series.sampling, "gap");
    assert_eq!(series.steps, "pairwise");
}

/// Cyclic sampling visits every block exactly once per pass: after one
/// outer iteration every working set is non-empty and the oracle-call
/// count equals n per pass.
#[test]
fn cyclic_sampling_visits_every_block_each_pass() {
    let problem = usps_tiny(1);
    let n = problem.n() as u64;
    let mut eng = NativeEngine;
    let cfg = MpBcfwConfig {
        max_iters: 3,
        sampling: SamplingStrategy::Cyclic,
        ..MpBcfwConfig::mp_paper(0.02)
    };
    let (series, run) = mp_bcfw::run(&problem, &mut eng, &cfg);
    assert_eq!(series.points.last().unwrap().oracle_calls, 3 * n);
    assert!(run.working_sets.iter().all(|w| !w.is_empty()));
    assert!(run.gaps.initialized());
}

//! Integration coverage for the §3.3/§3.4 working-set eviction rules and
//! the id contract the §3.5 Gram cache depends on: cap-N longest-inactive
//! eviction, TTL-T expiry, tag refresh on reinsert, and stable entry ids
//! across evictions (ids are never reused, so cached inner products can
//! never be served for the wrong plane).

use mpbcfw::coordinator::products::GramCache;
use mpbcfw::coordinator::working_set::WorkingSet;
use mpbcfw::model::plane::Plane;
use mpbcfw::model::plane::PlaneVec;

fn plane(tag: u64, vals: &[f64]) -> Plane {
    let pairs: Vec<(u32, f64)> =
        vals.iter().enumerate().map(|(i, &v)| (i as u32, v)).collect();
    Plane::new(PlaneVec::sparse(4, pairs), 0.1 * tag as f64, tag)
}

fn tags(ws: &WorkingSet) -> Vec<u64> {
    ws.entries().iter().map(|e| e.tag).collect()
}

#[test]
fn cap_evicts_longest_inactive_not_oldest_inserted() {
    let mut ws = WorkingSet::new(2);
    ws.insert(plane(1, &[1.0]), 0);
    ws.insert(plane(2, &[2.0]), 1);
    // Tag 1 was inserted first but is the most recently active.
    ws.touch(0, 5);
    ws.insert(plane(3, &[3.0]), 6);
    assert_eq!(ws.len(), 2);
    let t = tags(&ws);
    assert!(t.contains(&1) && t.contains(&3), "victim must be tag 2 (inactive longest): {t:?}");
}

#[test]
fn ttl_expiry_is_inclusive_at_the_cutoff() {
    let mut ws = WorkingSet::new(100);
    ws.insert(plane(1, &[1.0]), 2); // last_active 2
    ws.insert(plane(2, &[2.0]), 7); // last_active 7 = cutoff → kept
    ws.insert(plane(3, &[3.0]), 9);
    // cutoff = now - ttl = 10 - 3 = 7; entries with last_active >= 7 stay.
    let evicted = ws.evict_stale(10, 3);
    assert_eq!(evicted, 1);
    assert_eq!(tags(&ws), vec![2, 3]);
}

#[test]
fn reinsert_refreshes_tag_without_new_entry_or_new_id() {
    let mut ws = WorkingSet::new(10);
    ws.insert(plane(7, &[1.0]), 0);
    let id_before = ws.id(0);
    let idx = ws.insert(plane(7, &[1.0]), 4);
    assert_eq!(ws.len(), 1, "same-tag reinsert must dedup");
    assert_eq!(idx, 0);
    assert_eq!(ws.entries()[0].last_active, 4, "activity refreshed");
    assert_eq!(ws.id(0), id_before, "dedup keeps the stable id");
    // A refreshed entry survives a TTL sweep that would have killed the
    // original insertion time.
    assert_eq!(ws.evict_stale(6, 3), 0);
    assert_eq!(ws.len(), 1);
}

#[test]
fn ids_are_never_reused_across_evictions() {
    let mut ws = WorkingSet::new(2);
    let mut all_ids: Vec<u64> = Vec::new();
    let mut prev_newest: Option<u64> = None;
    for t in 0..20u64 {
        ws.insert(plane(100 + t, &[t as f64 + 1.0]), t);
        let step_ids: Vec<u64> = (0..ws.len()).map(|i| ws.id(i)).collect();
        let newest = *step_ids.iter().max().unwrap();
        if let Some(prev) = prev_newest {
            assert!(newest > prev, "a fresh insert must mint a strictly larger id");
        }
        prev_newest = Some(newest);
        all_ids.extend(step_ids);
        ws.evict_stale(t, 2);
    }
    // 20 distinct tags inserted → 20 distinct ids handed out, none
    // recycled from evicted entries.
    let mut uniq = all_ids;
    uniq.sort_unstable();
    uniq.dedup();
    assert_eq!(uniq.len(), 20);
}

#[test]
fn gram_cache_stays_consistent_across_evictions() {
    let mut ws = WorkingSet::new(2);
    let p1 = plane(1, &[1.0, 0.0, 0.0]);
    let p2 = plane(2, &[0.0, 2.0, 0.0]);
    let p3 = plane(3, &[3.0, 4.0, 0.0]);
    ws.insert(p1, 0);
    ws.insert(p2.clone(), 1);
    // Pin the id-keyed legacy backend explicitly: this test asserts the
    // id contract (and `len()` counting) of the hashmap store. The
    // default triangular arena keys by slab slot + generation instead
    // and is covered by `recycled_slot_invalidates_its_products` and
    // the backend-parity prop tests in `coordinator::products`.
    let mut gram = GramCache::hashmap();
    // Warm the cache with ⟨p1, p2⟩ = 0 under ids (0, 1).
    assert_eq!(gram.get(&ws, 0, 1), 0.0);
    assert_eq!(gram.misses, 1);

    // Insert p3: cap 2 evicts p1 (longest inactive). Entries are now
    // p2 (id 1) and p3 (id 2) — the (index 0, index 1) pair maps to a
    // *different* id key, so the stale ⟨p1, p2⟩ value cannot be served.
    ws.insert(p3.clone(), 2);
    assert_eq!(tags(&ws), vec![2, 3]);
    let v = gram.get(&ws, 0, 1);
    assert_eq!(v, 0.0 * 3.0 + 2.0 * 4.0, "fresh product ⟨p2, p3⟩ = 8");
    assert_eq!(gram.misses, 2, "new id pair is a miss, not a stale hit");

    // The surviving pair keeps hitting the cache.
    let hits_before = gram.hits;
    assert_eq!(gram.get(&ws, 0, 1), v);
    assert_eq!(gram.hits, hits_before + 1);

    // Dropping dead ids shrinks the cache without touching live entries.
    let alive: Vec<u64> = (0..ws.len()).map(|i| ws.id(i)).collect();
    gram.retain_ids(&|id| alive.contains(&id));
    assert_eq!(gram.len(), 1);
    assert_eq!(gram.get(&ws, 0, 1), v);
}

#[test]
fn norms_follow_entries_through_cap_and_ttl_eviction() {
    let mut ws = WorkingSet::new(3);
    for t in 0..12u64 {
        ws.insert(plane(t, &[t as f64, 1.0]), t);
        if t % 3 == 0 {
            ws.evict_stale(t, 2);
        }
        for idx in 0..ws.len() {
            let expect = ws.plane_ref(idx).star.norm_sq();
            assert!(
                (ws.norm_sq(idx) - expect).abs() < 1e-12,
                "norm cache out of sync at t={t} idx={idx}"
            );
        }
    }
}

//! Integration coverage for the plane representation layer
//! (`model::plane::PlaneVec`) across the whole training stack:
//!
//! * the representation-invariance contract end to end — `--dense-planes`
//!   and the default sparse storage produce **bit-identical** eval
//!   trajectories at a fixed seed on horseseg_like and ocr_like (the
//!   `PlaneVec` kernels accumulate in index order regardless of storage);
//! * the auto-compaction density thresholds;
//! * Gram-cache id stability when sparse-stored planes are evicted and
//!   replaced by dense-stored ones (and vice versa);
//! * the plane-storage metrics (`plane_bytes`, `plane_nnz_mean`) that
//!   make the sparsity win measurable in `bench --table sparsity`.

use mpbcfw::coordinator::products::GramCache;
use mpbcfw::coordinator::trainer::{train, Algo, DatasetKind, TrainSpec};
use mpbcfw::coordinator::working_set::WorkingSet;
use mpbcfw::data::types::Scale;
use mpbcfw::model::plane::{DENSIFY_ABOVE, Plane, PlaneVec, SPARSIFY_BELOW};

fn spec(ds: DatasetKind, dense_planes: bool) -> TrainSpec {
    TrainSpec {
        dataset: ds,
        scale: Scale::Tiny,
        algo: Algo::MpBcfw,
        max_iters: 5,
        seed: 11,
        data_seed: 3,
        // The §3.4 slope rule is timing-based; pin the pass schedule so
        // the two storage modes execute the identical step sequence.
        auto_approx: false,
        max_approx_passes: 2,
        dense_planes,
        ..Default::default()
    }
}

fn assert_bit_identical_trajectories(ds: DatasetKind) {
    let a = train(&spec(ds, false)).unwrap();
    let b = train(&spec(ds, true)).unwrap();
    assert_eq!(a.plane_repr, "sparse");
    assert_eq!(b.plane_repr, "dense");
    assert_eq!(a.points.len(), b.points.len());
    for (p, q) in a.points.iter().zip(&b.points) {
        assert_eq!(p.outer, q.outer);
        assert_eq!(p.oracle_calls, q.oracle_calls);
        assert_eq!(p.primal, q.primal, "primal diverged at outer {}", p.outer);
        assert_eq!(p.dual, q.dual, "dual diverged at outer {}", p.outer);
        assert_eq!(p.approx_passes, q.approx_passes);
        assert_eq!(p.approx_steps, q.approx_steps);
        assert_eq!(p.ws_mean, q.ws_mean);
        assert!(
            p.gap_est == q.gap_est || (p.gap_est.is_nan() && q.gap_est.is_nan()),
            "gap_est diverged at outer {}: {} vs {}",
            p.outer,
            p.gap_est,
            q.gap_est
        );
    }
    // Storage is the only thing allowed to differ; dense can never be
    // smaller than the compacted representation.
    let (pa, pb) = (a.points.last().unwrap(), b.points.last().unwrap());
    assert!(pa.plane_bytes > 0 && pb.plane_bytes > 0);
    assert!(pb.plane_bytes >= pa.plane_bytes);
    assert!(pb.plane_nnz_mean >= pa.plane_nnz_mean);
}

#[test]
fn dense_and_sparse_trajectories_bit_identical_on_horseseg_like() {
    assert_bit_identical_trajectories(DatasetKind::HorsesegLike);
}

#[test]
fn dense_and_sparse_trajectories_bit_identical_on_ocr_like() {
    assert_bit_identical_trajectories(DatasetKind::OcrLike);
}

#[test]
fn multiclass_planes_actually_stored_sparse() {
    // The sparsity machinery must be exercised, not vacuous. Multiclass
    // planes touch exactly two of K class blocks (density 2/K < the
    // densify threshold by construction), so in the default mode every
    // nonzero cached plane is sparse-stored and forcing dense storage
    // costs strictly more. (OCR and graph-cut planes have data-dependent
    // density and may legitimately auto-densify; the trajectory tests
    // above only require `>=` there.)
    let a = train(&spec(DatasetKind::UspsLike, false)).unwrap();
    let b = train(&spec(DatasetKind::UspsLike, true)).unwrap();
    let (pa, pb) = (a.points.last().unwrap(), b.points.last().unwrap());
    assert!(
        pb.plane_bytes > pa.plane_bytes,
        "dense {} bytes should exceed sparse {} bytes on usps_like",
        pb.plane_bytes,
        pa.plane_bytes
    );
    assert!(pb.plane_nnz_mean > pa.plane_nnz_mean);
}

// ---- PlaneVec compaction thresholds ---------------------------------

#[test]
fn sparse_builder_densifies_only_above_threshold() {
    // Just below the threshold: stays sparse.
    let at = (DENSIFY_ABOVE * 100.0) as u32; // 50 entries of 100
    let below = PlaneVec::sparse(100, (0..at).map(|i| (i, 1.0)).collect());
    assert!(!below.is_dense(), "density {} must stay sparse", below.density());
    // Just above: densifies.
    let above = PlaneVec::sparse(100, (0..at + 1).map(|i| (i, 1.0)).collect());
    assert!(above.is_dense(), "density {} must densify", above.density());
    // Values survive compaction exactly.
    assert_eq!(above.to_dense()[..51], vec![1.0; 51][..]);
    assert_eq!(above.to_dense()[51..], vec![0.0; 49][..]);
}

#[test]
fn compact_resparsifies_only_below_threshold() {
    let d = 100usize;
    let nnz_keep = (SPARSIFY_BELOW * d as f64) as usize; // 25: not < threshold
    let mut v = vec![0.0; d];
    for x in v.iter_mut().take(nnz_keep) {
        *x = 2.0;
    }
    assert!(PlaneVec::dense(v.clone()).compact().is_dense(), "at the threshold: keep dense");
    let mut v2 = vec![0.0; d];
    for x in v2.iter_mut().take(nnz_keep - 1) {
        *x = 2.0;
    }
    let re = PlaneVec::dense(v2.clone()).compact();
    assert!(!re.is_dense(), "below the threshold: re-sparsify");
    assert_eq!(re.nnz(), nnz_keep - 1);
    assert_eq!(re.to_dense(), v2);
}

#[test]
fn compaction_is_bitwise_neutral_for_all_kernels() {
    // Whatever representation compaction picks, every reduction agrees
    // bit for bit with the explicit dense storage of the same values.
    let dim = 64usize;
    let pairs: Vec<(u32, f64)> = (0..dim as u32)
        .filter(|i| i % 3 == 0)
        .map(|i| (i, (i as f64 * 0.37).sin()))
        .collect();
    let compacted = PlaneVec::sparse(dim, pairs.clone());
    let dense = {
        let mut v = vec![0.0; dim];
        for &(i, x) in &pairs {
            v[i as usize] = x;
        }
        PlaneVec::dense(v)
    };
    let w: Vec<f64> = (0..dim).map(|i| (i as f64 * 0.11).cos()).collect();
    assert_eq!(compacted.dot_dense(&w), dense.dot_dense(&w));
    assert_eq!(compacted.norm_sq(), dense.norm_sq());
    let other = PlaneVec::sparse(dim, vec![(0, 1.0), (3, -2.0), (63, 0.5)]);
    assert_eq!(compacted.dot(&other), dense.dot(&other));
    let mut acc1 = w.clone();
    let mut acc2 = w.clone();
    compacted.axpy_into(-0.7, &mut acc1);
    dense.axpy_into(-0.7, &mut acc2);
    assert_eq!(acc1, acc2);
    let mut acc1 = w.clone();
    let mut acc2 = w;
    compacted.interp_into(0.3, &mut acc1);
    dense.interp_into(0.3, &mut acc2);
    assert_eq!(acc1, acc2);
}

// ---- Gram-cache id stability across sparse eviction ------------------

fn sparse_plane(tag: u64, dim: usize, stride: usize) -> Plane {
    let pairs: Vec<(u32, f64)> = (0..dim)
        .step_by(stride)
        .map(|i| (i as u32, (tag as f64 + 1.0) * (i as f64 + 0.5)))
        .collect();
    Plane::new(PlaneVec::sparse(dim, pairs), 0.1, tag)
}

#[test]
fn gram_cache_ids_stable_across_mixed_representation_eviction() {
    let dim = 24usize;
    let mut ws = WorkingSet::new(100);
    let mut gram = GramCache::new();
    // A mix of sparse- and dense-stored planes (stride 1 → density 1 →
    // auto-densified; larger strides stay sparse).
    for (t, stride) in [(1u64, 8usize), (2, 1), (3, 4), (4, 2)] {
        ws.insert(sparse_plane(t, dim, stride), t);
    }
    let reference =
        |ws: &WorkingSet, a: usize, b: usize| ws.plane_ref(a).star.dot(ws.plane_ref(b).star);
    // Warm every pair and validate against direct dots.
    for a in 0..ws.len() {
        for b in 0..ws.len() {
            assert_eq!(gram.get(&ws, a, b), reference(&ws, a, b), "warm ({a},{b})");
        }
    }
    let warm_misses = gram.misses;
    // Evict the stale half (tags 1 and 2), keeping ids 2 and 3 alive.
    let dead = ws.evict_stale_ids(5, 2);
    assert_eq!(dead.len(), 2);
    gram.retain_ids(&|id| !dead.contains(&id));
    // Surviving pairs are still served from cache, still correct.
    for a in 0..ws.len() {
        for b in 0..ws.len() {
            assert_eq!(gram.get(&ws, a, b), reference(&ws, a, b), "post-evict ({a},{b})");
        }
    }
    assert_eq!(gram.misses, warm_misses, "surviving pairs must hit the warm cache");
    // New planes get fresh ids — a recycled index must not alias an old
    // product even when the new plane has a different representation.
    ws.insert(sparse_plane(9, dim, 1), 6); // dense-stored newcomer
    for a in 0..ws.len() {
        for b in 0..ws.len() {
            assert_eq!(gram.get(&ws, a, b), reference(&ws, a, b), "post-insert ({a},{b})");
        }
    }
}

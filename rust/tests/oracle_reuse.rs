//! Integration coverage for the warm-start dynamic max-oracle layer:
//!
//! * the `BkGraph` warm-restart contract — after arbitrary
//!   `reset_tweights`/`update_tweights` sequences on a persistent graph,
//!   `maxflow_reuse` returns **bitwise identical** flow values and
//!   labelings to cold builds with the same capacities;
//! * end-to-end trajectory neutrality — `--oracle-reuse on` and `off`
//!   produce bit-identical eval series at a fixed seed on horseseg_like
//!   (the graph-cut scenario, where reuse actually persists solver
//!   state);
//! * per-worker arena isolation under sharded dispatch — each example's
//!   graph lives in exactly one worker arena, warm passes construct
//!   nothing, and `--threads 4` with reuse on still matches the
//!   sequential cold trajectory.

use mpbcfw::coordinator::parallel;
use mpbcfw::coordinator::trainer::{build_problem, train, Algo, DatasetKind, TrainSpec};
use mpbcfw::data::types::Scale;
use mpbcfw::maxflow::BkGraph;
use mpbcfw::model::problem::StructuredProblem;
use mpbcfw::model::scratch::OracleScratch;
use mpbcfw::utils::rng::Pcg;

fn spec(reuse: bool, threads: usize) -> TrainSpec {
    TrainSpec {
        dataset: DatasetKind::HorsesegLike,
        scale: Scale::Tiny,
        algo: Algo::MpBcfw,
        max_iters: 4,
        seed: 7,
        data_seed: 2,
        // The §3.4 slope rule is timing-based; pin the pass schedule so
        // the reuse modes execute the identical step sequence.
        auto_approx: false,
        max_approx_passes: 2,
        oracle_reuse: reuse,
        threads,
        ..Default::default()
    }
}

#[test]
fn warm_bk_graph_bitwise_matches_cold_after_tweight_updates() {
    // Randomized Potts-style instances: persistent graph vs cold rebuild
    // across rounds of fresh terminal capacities.
    let mut rng = Pcg::seeded(41);
    for trial in 0..25 {
        let n = 2 + rng.below(12);
        let m = rng.below(3 * n + 1);
        let edges: Vec<(u32, u32, f64, f64)> = (0..m)
            .map(|_| {
                let a = rng.below(n);
                let mut b = rng.below(n);
                if a == b {
                    b = (b + 1) % n;
                }
                // Potts graphs use symmetric unit-ish weights; vary them
                // anyway to stress the reset path.
                (a as u32, b as u32, rng.f64() * 2.0, rng.f64() * 2.0)
            })
            .collect();
        let mut warm = BkGraph::new(n, m);
        for &(a, b, c, rc) in &edges {
            warm.add_edge(a, b, c, rc);
        }
        for round in 0..5 {
            let tw: Vec<(f64, f64)> =
                (0..n).map(|_| (rng.f64() * 4.0, rng.f64() * 4.0)).collect();
            warm.reset_tweights();
            for (i, &(cs, ct)) in tw.iter().enumerate() {
                warm.update_tweights(i as u32, cs, ct);
            }
            let f_warm = warm.maxflow_reuse();
            let mut cold = BkGraph::new(n, m);
            for (i, &(cs, ct)) in tw.iter().enumerate() {
                cold.add_tweights(i as u32, cs, ct);
            }
            for &(a, b, c, rc) in &edges {
                cold.add_edge(a, b, c, rc);
            }
            let f_cold = cold.maxflow();
            assert_eq!(
                f_warm.to_bits(),
                f_cold.to_bits(),
                "trial {trial} round {round}: flow {f_warm} vs {f_cold} not bitwise equal"
            );
            for i in 0..n as u32 {
                assert_eq!(
                    warm.is_source_side(i),
                    cold.is_source_side(i),
                    "trial {trial} round {round}: labeling differs at node {i}"
                );
            }
        }
    }
}

#[test]
fn oracle_reuse_on_off_trajectories_bitwise_identical_on_horseseg() {
    let on = train(&spec(true, 0)).unwrap();
    let off = train(&spec(false, 0)).unwrap();
    assert_eq!(on.oracle_reuse, "on");
    assert_eq!(off.oracle_reuse, "off");
    assert_eq!(on.points.len(), off.points.len());
    for (p, q) in on.points.iter().zip(&off.points) {
        assert_eq!(p.outer, q.outer);
        assert_eq!(p.oracle_calls, q.oracle_calls);
        assert_eq!(p.primal, q.primal, "primal diverged at outer {}", p.outer);
        assert_eq!(p.dual, q.dual, "dual diverged at outer {}", p.outer);
        assert_eq!(p.approx_passes, q.approx_passes);
        assert_eq!(p.approx_steps, q.approx_steps);
        assert_eq!(p.ws_mean, q.ws_mean);
        assert!(
            p.gap_est == q.gap_est || (p.gap_est.is_nan() && q.gap_est.is_nan()),
            "gap_est diverged at outer {}: {} vs {}",
            p.outer,
            p.gap_est,
            q.gap_est
        );
    }
    // Both modes populate the oracle timing split.
    let (a, b) = (on.points.last().unwrap(), off.points.last().unwrap());
    assert!(a.oracle_solve_s > 0.0 && b.oracle_solve_s > 0.0);
    assert!(a.oracle_build_s >= 0.0 && b.oracle_build_s >= 0.0);
}

#[test]
fn threaded_warm_run_matches_sequential_cold_run() {
    // Thread-count invariance and reuse neutrality compose: 4 warm
    // worker arenas must reproduce the sequential cold trajectory.
    let warm4 = train(&spec(true, 4)).unwrap();
    let cold0 = train(&spec(false, 0)).unwrap();
    assert_eq!(warm4.points.len(), cold0.points.len());
    for (p, q) in warm4.points.iter().zip(&cold0.points) {
        assert_eq!(p.primal, q.primal, "primal diverged at outer {}", p.outer);
        assert_eq!(p.dual, q.dual, "dual diverged at outer {}", p.outer);
        assert_eq!(p.oracle_calls, q.oracle_calls);
    }
}

#[test]
fn worker_arenas_stay_isolated_under_sharded_dispatch() {
    let problem = build_problem(&spec(true, 0));
    let mut rng = Pcg::seeded(3);
    let w: Vec<f64> = (0..problem.dim()).map(|_| 0.1 * rng.normal()).collect();
    let order: Vec<usize> = (0..problem.n()).collect();
    let threads = 4usize;
    let mut arenas: Vec<OracleScratch> =
        (0..threads).map(|_| OracleScratch::new(true)).collect();
    let (pass1, _) = parallel::exact_pass_with(&problem, &w, &order, threads, &mut arenas);
    // Id-mod sharding: worker k's arena holds exactly the graphs of its
    // residue class (sizes match `shard_sizes` for a full pass) — no
    // example is ever built in two arenas.
    let held: Vec<usize> = arenas.iter().map(|a| a.arena.held()).collect();
    assert_eq!(held, parallel::shard_sizes(problem.n(), threads));
    assert_eq!(held.iter().sum::<usize>(), problem.n());
    let built: u64 = arenas.iter().map(|a| a.arena.built).sum();
    assert_eq!(built as usize, problem.n(), "pass 1 builds each graph exactly once");
    // A second pass over the same order is fully warm: zero builds, and
    // the planes match the cold dispatch bit for bit.
    let (pass2, _) = parallel::exact_pass_with(&problem, &w, &order, threads, &mut arenas);
    let built_after: u64 = arenas.iter().map(|a| a.arena.built).sum();
    assert_eq!(built_after, built, "warm pass must construct zero graphs");
    let (cold, _) = parallel::exact_pass(&problem, &w, &order, threads);
    for ((a, b), c) in pass1.iter().zip(&pass2).zip(&cold) {
        assert_eq!(a.tag, b.tag);
        assert_eq!(a.off, b.off);
        assert_eq!(a.tag, c.tag);
        assert_eq!(a.off, c.off);
    }
    // The pinning is by block id, not by position in the pass order, so
    // a *reshuffled* order (samplers permute every pass) is still fully
    // warm: zero builds, same arena occupancy, planes aligned with the
    // new order.
    let reversed: Vec<usize> = order.iter().rev().copied().collect();
    let (pass3, _) = parallel::exact_pass_with(&problem, &w, &reversed, threads, &mut arenas);
    assert_eq!(
        arenas.iter().map(|a| a.arena.built).sum::<u64>(),
        built,
        "a reshuffled warm pass must construct zero graphs"
    );
    assert_eq!(arenas.iter().map(|a| a.arena.held()).collect::<Vec<_>>(), held);
    for (p, q) in pass3.iter().zip(pass1.iter().rev()) {
        assert_eq!(p.tag, q.tag, "reshuffled pass planes misaligned");
        assert_eq!(p.off, q.off);
    }
}

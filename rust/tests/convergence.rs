//! Integration: convergence behaviour of the full optimizer stack on all
//! three scenarios — the invariants behind the paper's Figs. 3 and 4.

use mpbcfw::coordinator::trainer::{train, Algo, DatasetKind, TrainSpec};
use mpbcfw::data::types::Scale;

fn spec(dataset: DatasetKind, algo: Algo, iters: u64) -> TrainSpec {
    TrainSpec { dataset, scale: Scale::Tiny, algo, max_iters: iters, ..Default::default() }
}

#[test]
fn dual_monotone_and_gap_shrinks_on_every_dataset() {
    for dataset in DatasetKind::all() {
        for algo in [Algo::Bcfw, Algo::MpBcfw] {
            let s = train(&spec(dataset, algo, 8)).unwrap();
            for w in s.points.windows(2) {
                assert!(
                    w[1].dual >= w[0].dual - 1e-10,
                    "{dataset:?}/{algo:?}: dual decreased {} -> {}",
                    w[0].dual,
                    w[1].dual
                );
            }
            let first = &s.points[0];
            let last = s.points.last().unwrap();
            assert!(
                last.primal - last.dual < 0.5 * (first.primal - first.dual),
                "{dataset:?}/{algo:?}: gap didn't halve: {} -> {}",
                first.primal - first.dual,
                last.primal - last.dual
            );
            for p in &s.points {
                assert!(p.primal >= p.dual - 1e-9, "{dataset:?}/{algo:?}: weak duality");
            }
        }
    }
}

#[test]
fn mp_bcfw_oracle_convergence_dominates_bcfw_on_structured_tasks() {
    // The paper's Fig. 3 ordering: larger label spaces (OCR, HorseSeg)
    // benefit more from the working set. Equal exact-call budgets.
    for dataset in [DatasetKind::OcrLike, DatasetKind::HorsesegLike] {
        let bcfw = train(&spec(dataset, Algo::Bcfw, 8)).unwrap();
        let mp = train(&spec(dataset, Algo::MpBcfw, 8)).unwrap();
        assert_eq!(
            bcfw.points.last().unwrap().oracle_calls,
            mp.points.last().unwrap().oracle_calls
        );
        let gap_bcfw = bcfw.final_gap();
        let gap_mp = mp.final_gap();
        assert!(gap_mp <= gap_bcfw * 1.05, "{dataset:?}: mp {gap_mp} vs bcfw {gap_bcfw}");
    }
}

#[test]
fn all_algorithms_approach_the_same_dual_optimum() {
    // BCFW, MP-BCFW and cutting-plane solve the same convex dual; run
    // them long on the same data and compare the optima they reach.
    let mut duals = Vec::new();
    for algo in [Algo::Bcfw, Algo::MpBcfw, Algo::CuttingPlane] {
        let s = train(&spec(DatasetKind::UspsLike, algo, 40)).unwrap();
        duals.push((algo, s.points.last().unwrap().dual));
    }
    let max = duals.iter().map(|(_, d)| *d).fold(f64::NEG_INFINITY, f64::max);
    for (algo, d) in &duals {
        assert!(
            (max - d) / max.abs().max(1e-12) < 0.05,
            "{algo:?} dual {d} far from best {max}"
        );
    }
}

#[test]
fn averaged_dual_is_still_a_lower_bound() {
    let avg = train(&spec(DatasetKind::UspsLike, Algo::MpBcfwAvg, 6)).unwrap();
    assert!(avg.points.iter().any(|p| p.primal_avg.is_some()));
    for p in &avg.points {
        if let Some(da) = p.dual_avg {
            assert!(da <= p.primal + 1e-9);
        }
    }
}

#[test]
fn working_set_shrinks_after_exploration_phase() {
    // Fig. 5: after an initial exploration phase the TTL rule prunes the
    // working sets down to the few relevant support planes.
    let s = train(&spec(DatasetKind::UspsLike, Algo::MpBcfw, 25)).unwrap();
    let peak = s.points.iter().map(|p| p.ws_mean).fold(0.0, f64::max);
    let last = s.points.last().unwrap().ws_mean;
    assert!(peak > 1.0, "working sets never grew (peak {peak})");
    assert!(last <= peak, "working set kept growing: last {last} vs peak {peak}");
}

#[test]
fn oracle_delay_inflates_measured_time_deterministically() {
    let fast = train(&spec(DatasetKind::UspsLike, Algo::Bcfw, 2)).unwrap();
    let slow = train(&TrainSpec {
        oracle_delay: 0.05,
        ..spec(DatasetKind::UspsLike, Algo::Bcfw, 2)
    })
    .unwrap();
    let calls = slow.points.last().unwrap().oracle_calls as f64;
    let t_fast = fast.points.last().unwrap().time;
    let t_slow = slow.points.last().unwrap().time;
    assert!(
        (t_slow - t_fast - 0.05 * calls).abs() < 0.2 * (0.05 * calls),
        "virtual delay not charged: fast {t_fast}, slow {t_slow}, calls {calls}"
    );
}

#[test]
fn lambda_sensitivity_smoke() {
    // The optimizer must stay stable across regularization scales.
    for lambda in [1e-4, 1e-2, 1.0] {
        let s = train(&TrainSpec {
            lambda: Some(lambda),
            ..spec(DatasetKind::UspsLike, Algo::MpBcfw, 6)
        })
        .unwrap();
        let last = s.points.last().unwrap();
        assert!(last.primal.is_finite() && last.dual.is_finite(), "λ={lambda}");
        assert!(last.primal >= last.dual - 1e-9, "λ={lambda}: weak duality");
    }
}

//! Lane contracts of the `--kernel {scalar,simd}` backend (see
//! `docs/ALGORITHMS.md`, 'Kernel backends').
//!
//! Two tiers, two contracts:
//!
//! * **Strict order (elementwise)** — axpy / scale_add / axpy_diff /
//!   interp / scal and the sparse scatter mirror perform independent
//!   per-lane IEEE ops with no FMA contraction, so the simd kernels are
//!   **bitwise identical** to their scalar originals on every input,
//!   including the `len % 4` tail. Property-tested on random slices.
//! * **Pinned reassociation (reductions)** — dot / dot2 / gather /
//!   merge-join fold four lane accumulators as `(l0+l1)+(l2+l3)`:
//!   deterministic and twin-reproducible, but a different summation
//!   order than scalar, so only a tolerance claim is made.
//!
//! End-to-end, `--kernel simd` therefore follows a bounded-drift
//! contract against the scalar golden anchor (checked here on the two
//! costly-oracle scenarios), and a fixed-seed simd run must reproduce
//! itself bitwise (twin determinism).

use mpbcfw::coordinator::trainer::{train, Algo, DatasetKind, TrainSpec};
use mpbcfw::data::types::Scale;
use mpbcfw::utils::math::{self, KernelBackend};
use mpbcfw::utils::prop::prop_check;

/// Bitwise slice equality (distinguishes 0.0 from -0.0 and NaN payloads,
/// which `==` would not).
fn same_bits(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn elementwise_simd_kernels_are_bitwise_scalar() {
    prop_check("axpy family: simd == scalar bitwise", 150, |g| {
        // Lengths straddle the 4-lane boundary: 0..=67 hits every tail
        // residue many times under shrinking.
        let n = g.usize(0, 67);
        let alpha = g.normal();
        let beta = g.normal();
        let x = g.vec_normal(n);
        let b = g.vec_normal(n);
        let y = g.vec_normal(n);

        let (mut ys, mut yv) = (y.clone(), y.clone());
        math::axpy(alpha, &x, &mut ys);
        math::axpy_simd(alpha, &x, &mut yv);
        if !same_bits(&ys, &yv) {
            return Err(format!("axpy diverged at n={n}"));
        }

        let (mut ys, mut yv) = (y.clone(), y.clone());
        math::scale_add(alpha, beta, &x, &mut ys);
        math::scale_add_simd(alpha, beta, &x, &mut yv);
        if !same_bits(&ys, &yv) {
            return Err(format!("scale_add diverged at n={n}"));
        }

        let (mut ys, mut yv) = (y.clone(), y.clone());
        math::axpy_diff(alpha, &x, &b, &mut ys);
        math::axpy_diff_simd(alpha, &x, &b, &mut yv);
        if !same_bits(&ys, &yv) {
            return Err(format!("axpy_diff diverged at n={n}"));
        }

        let gamma = g.f64(0.0, 1.0);
        let (mut ys, mut yv) = (y.clone(), y.clone());
        math::interp(gamma, &x, &mut ys);
        math::interp_simd(gamma, &x, &mut yv);
        if !same_bits(&ys, &yv) {
            return Err(format!("interp diverged at n={n}"));
        }

        let (mut ys, mut yv) = (y.clone(), y.clone());
        math::scal(alpha, &mut ys);
        math::scal_simd(alpha, &mut yv);
        if !same_bits(&ys, &yv) {
            return Err(format!("scal diverged at n={n}"));
        }
        Ok(())
    });
}

#[test]
fn sparse_scatter_simd_is_bitwise_scalar() {
    prop_check("scatter_axpy: simd == scalar bitwise", 150, |g| {
        let dim = g.usize(1, 80);
        let nnz = g.usize(0, dim);
        // Sorted unique indices — the PlaneVec invariant the simd
        // scatter relies on for lane-alias freedom.
        let mut idx: Vec<u32> = (0..dim as u32).collect();
        for i in (1..idx.len()).rev() {
            idx.swap(i, g.rng.below(i + 1));
        }
        idx.truncate(nnz);
        idx.sort_unstable();
        let val = g.vec_normal(idx.len());
        let alpha = g.normal();
        let y = g.vec_normal(dim);

        let mut ys = y.clone();
        for (&i, &v) in idx.iter().zip(&val) {
            ys[i as usize] += alpha * v;
        }
        let mut yv = y.clone();
        math::scatter_axpy_simd(alpha, &idx, &val, &mut yv);
        if !same_bits(&ys, &yv) {
            return Err(format!("scatter_axpy diverged at dim={dim}, nnz={nnz}"));
        }
        Ok(())
    });
}

#[test]
fn reduction_simd_kernels_match_scalar_within_tolerance() {
    prop_check("reductions: simd within reassociation tolerance", 150, |g| {
        let n = g.usize(0, 130);
        let a = g.vec_normal(n);
        let b = g.vec_normal(n);
        let p = g.vec_normal(n);
        // Reassociating a k-term sum perturbs by O(k·eps·Σ|aᵢbᵢ|).
        let scale: f64 =
            a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum::<f64>().max(1.0);
        let tol = 1e-13 * scale;

        let d = (math::dot(&a, &b) - math::dot_simd(&a, &b)).abs();
        if d > tol {
            return Err(format!("dot deviated by {d} (tol {tol}) at n={n}"));
        }
        let d = (math::dot_seq(&a, &b) - math::dot_seq_simd(&a, &b)).abs();
        if d > tol {
            return Err(format!("dot_seq deviated by {d} at n={n}"));
        }
        let (u_s, v_s) = math::dot2_seq(&p, &a, &b);
        let (u_v, v_v) = math::dot2_seq_simd(&p, &a, &b);
        if (u_s - u_v).abs() > tol || (v_s - v_v).abs() > tol {
            return Err(format!("dot2_seq deviated at n={n}"));
        }
        // Product-neutrality: the fused pair must equal two independent
        // single dots bitwise, on the simd backend like on scalar.
        if u_v.to_bits() != math::dot_seq_simd(&p, &a).to_bits()
            || v_v.to_bits() != math::dot_seq_simd(&p, &b).to_bits()
        {
            return Err(format!("dot2_seq_simd is not product-neutral at n={n}"));
        }
        Ok(())
    });
}

#[test]
fn merge_and_gather_simd_match_scalar_within_tolerance() {
    prop_check("sparse reductions: simd within tolerance", 150, |g| {
        let dim = g.usize(1, 90);
        let mk_sparse = |g: &mut mpbcfw::utils::prop::Gen, dim: usize| {
            let nnz = g.usize(0, dim);
            let mut idx: Vec<u32> = (0..dim as u32).collect();
            for i in (1..idx.len()).rev() {
                idx.swap(i, g.rng.below(i + 1));
            }
            idx.truncate(nnz);
            idx.sort_unstable();
            let val = g.vec_normal(idx.len());
            (idx, val)
        };
        let (ia, va) = mk_sparse(g, dim);
        let (ib, vb) = mk_sparse(g, dim);
        let w = g.vec_normal(dim);
        let tol = 1e-12 * (dim as f64).max(1.0);

        // gather_dot vs the scalar indexed loop.
        let scalar: f64 =
            ia.iter().zip(&va).map(|(&i, &v)| v * w[i as usize]).sum();
        let d = (scalar - math::gather_dot_simd(&ia, &va, &w)).abs();
        if d > tol {
            return Err(format!("gather_dot deviated by {d} at dim={dim}"));
        }

        // merge_dot vs the scalar merge-join.
        let (mut p, mut q, mut acc) = (0usize, 0usize, 0.0f64);
        while p < ia.len() && q < ib.len() {
            match ia[p].cmp(&ib[q]) {
                std::cmp::Ordering::Less => p += 1,
                std::cmp::Ordering::Greater => q += 1,
                std::cmp::Ordering::Equal => {
                    acc += va[p] * vb[q];
                    p += 1;
                    q += 1;
                }
            }
        }
        let d = (acc - math::merge_dot_simd(&ia, &va, &ib, &vb)).abs();
        if d > tol {
            return Err(format!("merge_dot deviated by {d} at dim={dim}"));
        }

        // gather_dot2 product-neutrality on the simd backend.
        let u = g.vec_normal(dim);
        let (x, y) = math::gather_dot2_simd(&ia, &va, &w, &u);
        if x.to_bits() != math::gather_dot_simd(&ia, &va, &w).to_bits()
            || y.to_bits() != math::gather_dot_simd(&ia, &va, &u).to_bits()
        {
            return Err(format!("gather_dot2 is not product-neutral at dim={dim}"));
        }
        Ok(())
    });
}

/// Pinned-schedule spec for the end-to-end drift/twin checks (the §3.4
/// rule is wall-clock-driven and would fork trajectories).
fn pinned_spec(dataset: DatasetKind, kernel: KernelBackend) -> TrainSpec {
    TrainSpec {
        dataset,
        scale: Scale::Tiny,
        algo: Algo::MpBcfw,
        seed: 3,
        max_iters: 4,
        auto_approx: false,
        max_approx_passes: 3,
        kernel,
        ..Default::default()
    }
}

#[test]
fn simd_run_tracks_scalar_within_drift_bound() {
    for dataset in [DatasetKind::HorsesegLike, DatasetKind::OcrLike] {
        let scalar = train(&pinned_spec(dataset, KernelBackend::Scalar)).unwrap();
        let simd = train(&pinned_spec(dataset, KernelBackend::Simd)).unwrap();
        assert_eq!(scalar.kernel_backend, "scalar");
        assert_eq!(simd.kernel_backend, "simd");
        assert_eq!(
            scalar.points.len(),
            simd.points.len(),
            "{dataset:?}: eval schedules diverged"
        );
        for (a, b) in scalar.points.iter().zip(&simd.points) {
            // Identical pass schedule: the oracle-call sequence cannot
            // depend on the arithmetic backend under a pinned schedule.
            assert_eq!(a.oracle_calls, b.oracle_calls, "{dataset:?}: schedule forked");
            let drift = (a.dual - b.dual).abs();
            assert!(
                drift <= 1e-8,
                "{dataset:?}: dual drift {drift} exceeds the reassociation bound"
            );
            assert!(b.primal >= b.dual - 1e-9, "{dataset:?}: weak duality under simd");
        }
        // Simd runs must record lane traffic; scalar runs must not.
        let last = simd.points.last().unwrap();
        assert!(last.simd_lane_elems + last.simd_tail_elems > 0);
        assert_eq!(scalar.points.last().unwrap().simd_lane_elems, 0);
    }
}

#[test]
fn simd_runs_are_twin_deterministic() {
    for dataset in [DatasetKind::HorsesegLike, DatasetKind::OcrLike] {
        let a = train(&pinned_spec(dataset, KernelBackend::Simd)).unwrap();
        let b = train(&pinned_spec(dataset, KernelBackend::Simd)).unwrap();
        let bits = |s: &mpbcfw::coordinator::metrics::Series| -> Vec<(u64, u64, u64)> {
            s.points
                .iter()
                .map(|p| (p.dual.to_bits(), p.primal.to_bits(), p.oracle_calls))
                .collect()
        };
        assert_eq!(
            bits(&a),
            bits(&b),
            "{dataset:?}: fixed-seed simd twins diverged — the pinned fold order leaked"
        );
    }
}

//! Whole-system integration: the bench harness produces the paper's
//! figure/table data end to end, and the headline result (Fig. 3/4
//! ordering) reproduces on every dataset at test scale.

use mpbcfw::bench::figures::{run_figures, FigureOpts};
use mpbcfw::bench::harness::RunGroup;
use mpbcfw::bench::tables::run_table;
use mpbcfw::coordinator::trainer::{Algo, DatasetKind, TrainSpec};
use mpbcfw::data::types::Scale;

fn tiny_opts() -> FigureOpts {
    FigureOpts { scale: Scale::Tiny, repeats: 2, max_iters: 4, ..Default::default() }
}

#[test]
fn full_figure_suite_emits_all_csvs() {
    let dir = std::env::temp_dir().join(format!("mpbcfw_e2e_figs_{}", std::process::id()));
    run_figures("all", &DatasetKind::all(), &tiny_opts(), &dir, |_| {}).unwrap();
    for ds in DatasetKind::all() {
        let p = dir.join(format!("fig34_{}.csv", ds.name()));
        let text = std::fs::read_to_string(&p).unwrap();
        // 4 algorithms × 2 seeds × (4+1) eval points + header.
        assert!(text.lines().count() >= 4 * 2 * 5, "{}", p.display());
        // Fig. 5/6 columns present with data for mp-bcfw rows.
        assert!(text.contains("mp-bcfw"));
        let header = text.lines().next().unwrap();
        for col in ["oracle_calls", "time_s", "primal_subopt", "dual_subopt", "ws_mean", "approx_passes"] {
            assert!(header.contains(col), "missing column {col}");
        }
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn full_table_suite_emits_all_csvs() {
    let dir = std::env::temp_dir().join(format!("mpbcfw_e2e_tabs_{}", std::process::id()));
    run_table("all", &[DatasetKind::UspsLike], &tiny_opts(), &dir, |_| {}).unwrap();
    for f in [
        "table_oracle_stats.csv",
        "table_crossover.csv",
        "table_product_cache.csv",
        "table_t_sweep.csv",
    ] {
        assert!(dir.join(f).exists(), "{f} missing");
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn headline_result_reproduces_on_all_datasets() {
    // Fig. 3's claim at integration-test scale: with the same number of
    // exact oracle calls, MP-BCFW's primal suboptimality is no worse than
    // BCFW's (and substantially better on the structured tasks).
    for dataset in DatasetKind::all() {
        let base = TrainSpec {
            dataset,
            scale: Scale::Tiny,
            max_iters: 6,
            ..Default::default()
        };
        let group = RunGroup::run(&base, &[Algo::Bcfw, Algo::MpBcfw], &[0, 1, 2], |_| {}).unwrap();
        let med = |algo: &str| -> f64 {
            let mut v: Vec<f64> = group
                .series
                .iter()
                .filter(|s| s.algo == algo)
                .map(|s| s.points.last().unwrap().primal - group.best_dual)
                .collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        let (bcfw, mp) = (med("bcfw"), med("mp-bcfw"));
        assert!(
            mp <= bcfw * 1.10 + 1e-12,
            "{dataset:?}: median mp-bcfw {mp} worse than bcfw {bcfw}"
        );
    }
}

#[test]
fn crossover_speedup_grows_with_oracle_cost() {
    // §4.1's runtime story, in miniature: make the oracle virtually
    // expensive and check MP-BCFW reaches BCFW's final gap sooner.
    let mk = |algo: Algo, delay: f64| TrainSpec {
        dataset: DatasetKind::UspsLike,
        scale: Scale::Tiny,
        algo,
        max_iters: 6,
        oracle_delay: delay,
        ..Default::default()
    };
    let delay = 0.01;
    let bcfw = mpbcfw::coordinator::trainer::train(&mk(Algo::Bcfw, delay)).unwrap();
    let target = bcfw.final_gap();
    let t_bcfw = bcfw.points.last().unwrap().time;
    let mp = mpbcfw::coordinator::trainer::train(&mk(Algo::MpBcfw, delay)).unwrap();
    let t_mp = mp
        .points
        .iter()
        .find(|p| p.primal - p.dual <= target)
        .map(|p| p.time)
        .unwrap_or(mp.points.last().unwrap().time);
    assert!(
        t_mp < t_bcfw,
        "with a {delay}s oracle, MP-BCFW ({t_mp}s) should reach BCFW's gap before BCFW ({t_bcfw}s)"
    );
}

//! Integration: related-work baselines (FW, cutting-plane, SSG) behave as
//! the paper's §2.1 describes relative to BCFW/MP-BCFW.

use mpbcfw::coordinator::trainer::{train, Algo, DatasetKind, TrainSpec};
use mpbcfw::data::types::Scale;

fn spec(algo: Algo, iters: u64) -> TrainSpec {
    TrainSpec {
        dataset: DatasetKind::UspsLike,
        scale: Scale::Tiny,
        algo,
        max_iters: iters,
        ..Default::default()
    }
}

#[test]
fn bcfw_beats_batch_fw_at_equal_oracle_calls() {
    // The founding observation of [15]: block-coordinate steps extract
    // more progress per oracle call than batch FW.
    let fw = train(&spec(Algo::Fw, 10)).unwrap();
    let bcfw = train(&spec(Algo::Bcfw, 10)).unwrap();
    assert_eq!(
        fw.points.last().unwrap().oracle_calls,
        bcfw.points.last().unwrap().oracle_calls
    );
    assert!(bcfw.final_gap() < fw.final_gap());
}

#[test]
fn cutting_plane_needs_few_iterations_but_full_sweeps() {
    let cp = train(&spec(Algo::CuttingPlane, 25)).unwrap();
    let last = cp.points.last().unwrap();
    // n calls per iteration.
    assert_eq!(last.oracle_calls % 60, 0);
    assert!(last.primal - last.dual < 0.5 * (cp.points[1].primal - cp.points[1].dual));
}

#[test]
fn ssg_has_no_dual_but_decreases_primal() {
    let ssg = train(&spec(Algo::SsgAvg, 15)).unwrap();
    assert!(ssg.points.iter().all(|p| p.dual == f64::NEG_INFINITY));
    let first = ssg.points.first().unwrap().primal;
    let last = ssg.points.last().unwrap().primal;
    assert!(last < first);
}

#[test]
fn frank_wolfe_family_certifies_via_gap_ssg_does_not() {
    // The FW-family's selling point: a duality-gap certificate at no
    // extra oracle cost. Make sure the plumbing reports it.
    let mp = train(&spec(Algo::MpBcfw, 10)).unwrap();
    let last = mp.points.last().unwrap();
    assert!(last.primal - last.dual >= -1e-9);
    assert!(last.primal - last.dual < 1e-2);
}

#[test]
fn mp_bcfw_at_least_matches_every_baseline_in_oracle_convergence() {
    // Sanity for the paper's positioning: at an equal exact-call budget
    // nothing in the shipped baseline set beats MP-BCFW's primal by a
    // meaningful margin on the tiny benchmark.
    let budget_iters = 10;
    let mp = train(&spec(Algo::MpBcfw, budget_iters)).unwrap();
    let mp_primal = mp.points.last().unwrap().primal;
    for algo in [Algo::Fw, Algo::Bcfw, Algo::CuttingPlane, Algo::Ssg, Algo::SsgAvg] {
        let s = train(&spec(algo, budget_iters)).unwrap();
        let p = {
            let lp = s.points.last().unwrap();
            lp.primal_avg.unwrap_or(lp.primal)
        };
        assert!(
            mp_primal <= p + 1e-3,
            "{algo:?} primal {p} beat MP-BCFW {mp_primal} at equal budget"
        );
    }
}

//! Integration tests for the sharded parallel exact-pass dispatch
//! (`coordinator::parallel` + the `threads` knob of MP-BCFW).
//!
//! The contract under test: oracle calls are computed against a per-pass
//! snapshot of w and the Frank-Wolfe steps are merged in permutation
//! order, so at a fixed seed the convergence trajectory is *identical*
//! for every thread count, and the atomic call counters stay exact under
//! concurrency.

use mpbcfw::coordinator::mp_bcfw::{self, MpBcfwConfig};
use mpbcfw::coordinator::parallel;
use mpbcfw::data::synth::usps_like::{generate, UspsLikeConfig};
use mpbcfw::data::types::Scale;
use mpbcfw::model::problem::StructuredProblem;
use mpbcfw::oracle::multiclass::MulticlassProblem;
use mpbcfw::oracle::wrappers::CountingOracle;
use mpbcfw::runtime::engine::NativeEngine;
use mpbcfw::utils::rng::Pcg;

fn tiny_problem(seed: u64) -> CountingOracle {
    CountingOracle::new(Box::new(MulticlassProblem::new(generate(
        UspsLikeConfig::at_scale(Scale::Tiny),
        seed,
    ))))
}

#[test]
fn same_seed_trajectory_matches_across_thread_counts() {
    // The fixed pass schedule (auto_approx off) removes the only
    // timing-dependent decision; everything else is deterministic.
    let mut all = Vec::new();
    for threads in [1usize, 4] {
        let problem = tiny_problem(5);
        let mut eng = NativeEngine;
        let cfg = MpBcfwConfig {
            max_iters: 6,
            seed: 11,
            threads,
            auto_approx: false,
            max_approx_passes: 2,
            ..MpBcfwConfig::mp_paper(1.0 / 60.0)
        };
        let (series, _) = mp_bcfw::run(&problem, &mut eng, &cfg);
        all.push(series);
    }
    let (a, b) = (&all[0], &all[1]);
    assert_eq!(a.points.len(), b.points.len());
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(
            pa.oracle_calls, pb.oracle_calls,
            "atomic oracle-call counts must match exactly"
        );
        assert!(
            (pa.dual - pb.dual).abs() <= 1e-9 * (1.0 + pa.dual.abs()),
            "dual trajectory diverged: {} vs {} at outer {}",
            pa.dual,
            pb.dual,
            pa.outer
        );
        assert!(
            (pa.primal - pb.primal).abs() <= 1e-9 * (1.0 + pa.primal.abs()),
            "primal trajectory diverged: {} vs {} at outer {}",
            pa.primal,
            pb.primal,
            pa.outer
        );
    }
}

#[test]
fn parallel_run_converges_with_defaults() {
    let problem = tiny_problem(3);
    let mut eng = NativeEngine;
    let cfg = MpBcfwConfig { max_iters: 10, threads: 4, ..MpBcfwConfig::mp_paper(1.0 / 60.0) };
    let (series, run) = mp_bcfw::run(&problem, &mut eng, &cfg);
    for w in series.points.windows(2) {
        assert!(w[1].dual >= w[0].dual - 1e-10, "dual decreased: {w:?}");
    }
    let first = &series.points[0];
    let last = series.points.last().unwrap();
    assert!(last.primal - last.dual < first.primal - first.dual);
    assert!(last.primal - last.dual >= -1e-9, "weak duality violated");
    assert!(run.state.consistency_error() < 1e-6);
    assert!(!series.shard_secs.is_empty(), "parallel runs must record shard timings");
    assert!(series.exact_pass_secs > 0.0);
}

#[test]
fn exact_pass_planes_match_sequential_oracle() {
    let problem = tiny_problem(1);
    let mut rng = Pcg::seeded(7);
    let w: Vec<f64> = (0..problem.dim()).map(|_| rng.normal()).collect();
    let order: Vec<usize> = (0..problem.n()).rev().collect();
    let (planes, report) = parallel::exact_pass(&problem, &w, &order, 3);
    assert_eq!(planes.len(), order.len());
    assert_eq!(report.shard_secs.len(), 3);
    let mut eng = NativeEngine;
    for (&i, p) in order.iter().zip(&planes) {
        let q = problem.inner().oracle(i, &w, &mut eng);
        assert_eq!(p.tag, q.tag, "plane mismatch at block {i}");
        assert_eq!(p.off, q.off);
    }
}

#[test]
fn virtual_latency_charged_for_critical_path_only() {
    // n = 60, threads = 4 → 15 calls per shard per pass. With BCFW
    // semantics (no approximate passes) and 2 outer iterations the
    // parallel run must be charged 2·15·delay of virtual time, not the
    // sequential 2·60·delay.
    let delay = 0.01;
    let problem = CountingOracle::with_delay(
        Box::new(MulticlassProblem::new(generate(UspsLikeConfig::at_scale(Scale::Tiny), 2))),
        delay,
    );
    let n = problem.n() as f64;
    let mut eng = NativeEngine;
    let cfg = MpBcfwConfig { max_iters: 2, threads: 4, ..MpBcfwConfig::bcfw(0.02) };
    let (series, _) = mp_bcfw::run(&problem, &mut eng, &cfg);
    let t = series.points.last().unwrap().time;
    let critical = 2.0 * (n / 4.0) * delay;
    let sequential = 2.0 * n * delay;
    assert!(t >= critical - 1e-9, "measured {t} < critical-path charge {critical}");
    assert!(
        t < sequential,
        "measured {t} should be far below the sequential charge {sequential}"
    );
    // The per-call oracle *stat* still accounts every virtual second.
    let st = problem.stats();
    assert!((st.virtual_secs - sequential).abs() < 1e-9);
}

#[test]
fn oracle_budget_is_exact_in_parallel_mode() {
    // n = 60; budget 90 → a full first pass (60) plus a truncated second
    // pass (30), never an overshoot (the sequential path breaks mid-pass
    // at exactly the budget; the parallel path truncates the dispatch).
    let problem = tiny_problem(1);
    let mut eng = NativeEngine;
    let cfg = MpBcfwConfig {
        max_iters: 100,
        max_oracle_calls: 90,
        threads: 4,
        ..MpBcfwConfig::mp_paper(0.02)
    };
    let (series, _) = mp_bcfw::run(&problem, &mut eng, &cfg);
    assert_eq!(series.points.last().unwrap().oracle_calls, 90);
    assert_eq!(problem.stats().calls, 90);
}

#[test]
fn counting_oracle_is_safe_under_scoped_threads() {
    let problem = tiny_problem(4);
    let w = vec![0.0; problem.dim()];
    let n = problem.n();
    std::thread::scope(|s| {
        for t in 0..4usize {
            let (problem, w) = (&problem, &w);
            s.spawn(move || {
                let mut eng = NativeEngine;
                for i in (t..n).step_by(4) {
                    problem.oracle(i, w, &mut eng);
                }
            });
        }
    });
    assert_eq!(problem.stats().calls, n as u64);
    assert_eq!(problem.stats().calls_all, n as u64);
    assert!(problem.stats().real_secs >= 0.0);
}

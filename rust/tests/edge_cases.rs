//! Edge cases and failure injection across the stack: degenerate
//! datasets, extreme configurations, numerical corner cases.

use mpbcfw::coordinator::dual::DualState;
use mpbcfw::coordinator::mp_bcfw::{self, MpBcfwConfig};
use mpbcfw::coordinator::products::{cached_block_updates, GramCache};
use mpbcfw::coordinator::trainer::{train, Algo, DatasetKind, TrainSpec};
use mpbcfw::coordinator::working_set::WorkingSet;
use mpbcfw::data::synth::usps_like::{generate, UspsLikeConfig};
use mpbcfw::data::types::Scale;
use mpbcfw::maxflow::BkGraph;
use mpbcfw::model::plane::Plane;
use mpbcfw::model::plane::PlaneVec;
use mpbcfw::oracle::multiclass::MulticlassProblem;
use mpbcfw::oracle::wrappers::CountingOracle;
use mpbcfw::runtime::engine::NativeEngine;

#[test]
fn single_example_dataset_trains() {
    let mut cfg = UspsLikeConfig::at_scale(Scale::Tiny);
    cfg.n = 1;
    let problem = CountingOracle::new(Box::new(MulticlassProblem::new(generate(cfg, 0))));
    let mut eng = NativeEngine;
    let mp = MpBcfwConfig { max_iters: 10, ..MpBcfwConfig::mp_paper(1.0) };
    let (series, run) = mp_bcfw::run(&problem, &mut eng, &mp);
    let last = series.points.last().unwrap();
    assert!(last.primal >= last.dual - 1e-12);
    assert!(run.state.consistency_error() < 1e-9);
}

#[test]
fn working_set_cap_one_still_converges() {
    let spec = TrainSpec {
        scale: Scale::Tiny,
        algo: Algo::MpBcfw,
        cap_n: 1,
        max_iters: 8,
        ..Default::default()
    };
    let s = train(&spec).unwrap();
    let last = s.points.last().unwrap();
    assert!(last.primal - last.dual < s.points[0].primal - s.points[0].dual);
    assert!(last.ws_mean <= 1.0 + 1e-12);
}

#[test]
fn ttl_zero_evicts_everything_each_iteration() {
    let spec = TrainSpec {
        scale: Scale::Tiny,
        algo: Algo::MpBcfw,
        ttl: 0,
        max_iters: 4,
        ..Default::default()
    };
    let s = train(&spec).unwrap();
    // With TTL 0 only planes touched in the current iteration survive;
    // training must still be sound (dual monotone).
    for w in s.points.windows(2) {
        assert!(w[1].dual >= w[0].dual - 1e-10);
    }
}

#[test]
fn zero_iterations_yields_initial_point_only() {
    let spec = TrainSpec { scale: Scale::Tiny, max_iters: 0, ..Default::default() };
    let s = train(&spec).unwrap();
    assert_eq!(s.points.len(), 1);
    assert_eq!(s.points[0].oracle_calls, 0);
    assert_eq!(s.points[0].dual, 0.0);
}

#[test]
fn huge_lambda_drives_weights_to_zero() {
    let spec = TrainSpec {
        scale: Scale::Tiny,
        lambda: Some(1e6),
        max_iters: 5,
        ..Default::default()
    };
    let s = train(&spec).unwrap();
    let last = s.points.last().unwrap();
    // P(w*) ≈ P(0) = mean structured loss at w=0 (weights can't move).
    assert!((last.primal - s.points[0].primal).abs() < 0.1 * s.points[0].primal + 1e-9);
}

#[test]
fn duplicate_oracle_planes_do_not_bloat_working_set() {
    // At the optimum the oracle keeps returning the same labelings; the
    // tag-dedup in WorkingSet::insert must keep |W_i| small.
    let spec = TrainSpec {
        scale: Scale::Tiny,
        algo: Algo::MpBcfw,
        max_iters: 30,
        ttl: 1000, // disable TTL so only dedup bounds the set
        ..Default::default()
    };
    let s = train(&spec).unwrap();
    let last = s.points.last().unwrap();
    assert!(
        last.ws_mean < 15.0,
        "working sets grew unboundedly despite dedup: {}",
        last.ws_mean
    );
}

#[test]
fn gram_cache_survives_working_set_eviction() {
    // Stale Gram keys must never corrupt results: evict entries between
    // cached visits and check the state stays consistent.
    let dim = 12;
    let mut st = DualState::new(1, dim, 0.5);
    let mut ws = WorkingSet::new(100);
    let mut gram = GramCache::new();
    let mut rng = mpbcfw::utils::rng::Pcg::seeded(9);
    for round in 0..10u64 {
        for t in 0..4 {
            let pairs: Vec<(u32, f64)> =
                (0..dim).map(|_| (rng.below(dim) as u32, rng.normal())).collect();
            let p = Plane::new(PlaneVec::sparse(dim, pairs), rng.normal(), round * 100 + t);
            ws.insert(p, round);
        }
        cached_block_updates(&mut st, &mut ws, &mut gram, 0, 6, round, &mut Vec::new());
        ws.evict_stale(round, 1);
        assert!(st.consistency_error() < 1e-8, "round {round}");
    }
    // retain_ids drops dead keys without breaking live ones.
    let live: Vec<u64> = ws.entries().iter().map(|e| e.id).collect();
    gram.retain_ids(&move |id| live.contains(&id));
    cached_block_updates(&mut st, &mut ws, &mut gram, 0, 6, 11, &mut Vec::new());
    assert!(st.consistency_error() < 1e-8);
}

#[test]
fn bk_handles_disconnected_and_saturated_graphs() {
    // No edges at all: flow = sum of min(t-weights).
    let mut g = BkGraph::new(3, 0);
    g.add_tweights(0, 2.0, 1.0);
    g.add_tweights(1, 0.0, 5.0);
    g.add_tweights(2, 3.0, 0.0);
    assert_eq!(g.maxflow(), 1.0);
    assert!(g.is_source_side(0));
    assert!(!g.is_source_side(1));
    assert!(g.is_source_side(2));

    // Zero-capacity edges behave like no edges.
    let mut g = BkGraph::new(2, 1);
    g.add_tweights(0, 1.0, 0.0);
    g.add_tweights(1, 0.0, 1.0);
    g.add_edge(0, 1, 0.0, 0.0);
    assert_eq!(g.maxflow(), 0.0);

    // Very large capacities don't overflow the f64 bookkeeping.
    let mut g = BkGraph::new(2, 1);
    g.add_tweights(0, 1e15, 0.0);
    g.add_tweights(1, 0.0, 1e15);
    g.add_edge(0, 1, 1e15, 1e15);
    assert_eq!(g.maxflow(), 1e15);
}

#[test]
fn line_search_with_zero_norm_planes_is_safe() {
    // Ground-truth planes are identically zero; repeated zero steps must
    // not NaN the state.
    let mut st = DualState::new(2, 4, 1.0);
    let zero = Plane::zero(4);
    for _ in 0..5 {
        let g = st.block_step(0, &zero);
        assert_eq!(g, 0.0);
    }
    assert!(st.dual_value() == 0.0);
    assert!(st.consistency_error() == 0.0);
}

#[test]
fn max_time_budget_stops_early() {
    let spec = TrainSpec {
        scale: Scale::Tiny,
        algo: Algo::Bcfw,
        max_iters: 10_000,
        max_time: 0.05,
        oracle_delay: 0.001, // virtual: each pass charges 60 ms
        ..Default::default()
    };
    let s = train(&spec).unwrap();
    let last = s.points.last().unwrap();
    assert!(last.outer < 10_000, "time budget ignored (ran {} iters)", last.outer);
}

#[test]
fn target_gap_stops_early() {
    let spec = TrainSpec {
        scale: Scale::Tiny,
        algo: Algo::MpBcfw,
        max_iters: 10_000,
        target_gap: 1e-3,
        ..Default::default()
    };
    let s = train(&spec).unwrap();
    let last = s.points.last().unwrap();
    assert!(last.primal - last.dual <= 1e-3 + 1e-12);
    assert!(last.outer < 10_000);
}

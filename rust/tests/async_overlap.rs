//! Async-overlap conformance: the `--async off` ⇄ `--async on
//! --max-stale-epochs 0` bitwise-equivalence contract, and the bounded
//! drift + monotone-dual contract of genuinely overlapped runs under
//! adversarial completion orderings (driven by the deterministic
//! [`VirtualExecutor`] — no wall-clock dependence anywhere in here).
//!
//! The `--async off` anchor itself is pinned across PRs by
//! `tests/golden_trajectory.rs`: its fixtures replay `TrainSpec`s built
//! with `..Default::default()`, and the default `async_mode` is `Off`,
//! so the golden duals transitively gate the synchronous driver this
//! suite compares against.

use mpbcfw::coordinator::async_overlap::{
    run_async_with, AsyncMode, CompletionOrder, VirtualExecutor,
};
use mpbcfw::coordinator::metrics::Series;
use mpbcfw::coordinator::mp_bcfw::{self, MpBcfwConfig};
use mpbcfw::data::synth::usps_like::{generate, UspsLikeConfig};
use mpbcfw::data::types::Scale;
use mpbcfw::oracle::multiclass::MulticlassProblem;
use mpbcfw::oracle::wrappers::CountingOracle;
use mpbcfw::runtime::engine::NativeEngine;

fn tiny_problem() -> CountingOracle {
    CountingOracle::new(Box::new(MulticlassProblem::new(generate(
        UspsLikeConfig::at_scale(Scale::Tiny),
        1,
    ))))
}

/// The pinned base config of every run here: `auto_approx` off (the
/// §3.4 rule is wall-clock-driven and would fork twin trajectories)
/// and a fixed approximate-pass budget.
fn cfg(async_mode: AsyncMode, max_stale_epochs: u64) -> MpBcfwConfig {
    MpBcfwConfig {
        max_iters: 5,
        auto_approx: false,
        max_approx_passes: 2,
        threads: 2,
        seed: 7,
        async_mode,
        max_stale_epochs,
        ..MpBcfwConfig::mp_paper(1.0 / 60.0)
    }
}

fn sync_series() -> Series {
    let problem = tiny_problem();
    let mut eng = NativeEngine;
    let (series, _) = mp_bcfw::run(&problem, &mut eng, &cfg(AsyncMode::Off, 1));
    series
}

fn async_series(order: CompletionOrder, workers: usize, stale: u64) -> Series {
    let problem = tiny_problem();
    let mut eng = NativeEngine;
    let c = MpBcfwConfig { threads: workers, ..cfg(AsyncMode::On, stale) };
    let mut exec = VirtualExecutor::new(&problem, workers, c.oracle_reuse, order);
    let (series, _) = run_async_with(&problem, &mut eng, &c, &mut exec);
    series
}

/// The trajectory identity of a series: (dual bits, primal bits,
/// exact-oracle calls) per evaluation point. Timing columns are
/// excluded — they are wall-clock-derived and legitimately differ.
fn bits(s: &Series) -> Vec<(u64, u64, u64)> {
    s.points
        .iter()
        .map(|p| (p.dual.to_bits(), p.primal.to_bits(), p.oracle_calls))
        .collect()
}

#[test]
fn stale_zero_is_bitwise_identical_to_async_off() {
    // K = 0 degenerates the async driver to synchronous dispatch:
    // everything dispatched in an epoch folds inside that epoch, in
    // dispatch order — exactly the sharded synchronous pass. The
    // contract is bitwise, for any worker count.
    let off = sync_series();
    assert_eq!(off.async_mode, "off");
    for workers in [1usize, 2] {
        let on = async_series(CompletionOrder::Fifo, workers, 0);
        assert_eq!(on.async_mode, "on");
        assert_eq!(
            bits(&off),
            bits(&on),
            "async on/K=0 with {workers} worker(s) diverged from async off"
        );
        let last = on.points.last().unwrap();
        // Synchronous dispatch never folds a stale plane.
        assert_eq!(last.mean_snapshot_staleness, 0.0);
        assert_eq!(last.stale_rejects, 0);
    }
}

#[test]
fn stale_zero_is_invariant_under_completion_order() {
    // At K = 0 the fold queue (strict dispatch order) decides the merge
    // sequence; arrival timing decides nothing. Adversarial completion
    // orders must therefore not move a single bit.
    let fifo = async_series(CompletionOrder::Fifo, 2, 0);
    for order in [
        CompletionOrder::Reversed,
        CompletionOrder::Interleaved,
        CompletionOrder::Starve(0),
    ] {
        let adv = async_series(order, 2, 0);
        assert_eq!(bits(&fifo), bits(&adv), "{order:?} moved the K=0 trajectory");
    }
}

#[test]
fn overlapped_runs_stay_monotone_and_weakly_dual_under_adversarial_orders() {
    let sync_last = sync_series().points.last().unwrap().dual;
    assert!(sync_last > 0.0, "sync reference made no progress");
    for order in [
        CompletionOrder::Fifo,
        CompletionOrder::Reversed,
        CompletionOrder::Interleaved,
        CompletionOrder::Starve(0),
    ] {
        let s = async_series(order, 2, 2);
        for p in &s.points {
            assert!(p.primal >= p.dual - 1e-8, "{order:?}: weak duality violated at {p:?}");
        }
        for w in s.points.windows(2) {
            assert!(
                w[1].dual >= w[0].dual - 1e-10,
                "{order:?}: dual decreased {} -> {} (monotone fold guard broken)",
                w[0].dual,
                w[1].dual
            );
        }
        // Bounded drift: overlap may cost progress vs the synchronous
        // trajectory, but not collapse it.
        let last = s.points.last().unwrap().dual;
        assert!(
            last >= 0.25 * sync_last,
            "{order:?}: async dual {last} lost the sync reference {sync_last}"
        );
    }
}

#[test]
fn overlapped_runs_are_deterministic_twins() {
    // Same config + same executor schedule ⇒ bitwise-identical series,
    // even for genuinely overlapped (K ≥ 1) adversarial runs.
    for order in [
        CompletionOrder::Reversed,
        CompletionOrder::Interleaved,
        CompletionOrder::Starve(1),
    ] {
        let a = async_series(order, 2, 2);
        let b = async_series(order, 2, 2);
        assert_eq!(bits(&a), bits(&b), "{order:?}: twin overlapped runs diverged");
    }
}

#[test]
fn starved_worker_forces_stale_folds_onto_the_guard() {
    // Starving worker 0 holds half the blocks' planes in flight until
    // the K = 2 throttle (or the final epoch) forces a drain, so folds
    // arrive against a moved w: the run must exercise the stale path —
    // planes folded at staleness ≥ 1 (visible in the mean) and/or
    // monotone-guard rejections — while the trajectory above stays
    // monotone.
    let s = async_series(CompletionOrder::Starve(0), 2, 2);
    let last = s.points.last().unwrap();
    assert!(
        last.planes_folded_async > 0 || last.stale_rejects > 0,
        "starvation never exercised the stale-fold path: {last:?}"
    );
    assert!(
        last.mean_snapshot_staleness > 0.0 || last.stale_rejects > 0,
        "every fold reported staleness 0 despite a starved worker: {last:?}"
    );
}

#[test]
fn forced_epoch_gap_triggers_stale_rejects() {
    // Tighter variant of the guard check: one worker, everything
    // starved, so nothing folds until the throttle forces it several
    // epochs late. Folding the same block's stale planes repeatedly
    // must eventually hit the non-improving case and requeue (the
    // monotone guard) — and the dual still never decreases.
    let s = async_series(CompletionOrder::Starve(0), 1, 3);
    for w in s.points.windows(2) {
        assert!(w[1].dual >= w[0].dual - 1e-10, "guard let the dual decrease");
    }
    let last = s.points.last().unwrap();
    assert!(
        last.planes_folded_async + last.stale_rejects > 0,
        "fully starved run recorded no async fold activity: {last:?}"
    );
}

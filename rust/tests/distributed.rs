//! Distributed-training conformance: the crash-safe loopback cluster
//! end to end. The anchor is bitwise: a same-seed 1-coordinator +
//! N-worker run must reproduce the single-process trajectory exactly
//! (dual, primal, oracle-call counts per eval point) — planes are pure
//! in `(block, snapshot-w)` and the coordinator merges them in the
//! sampled block order, so neither the worker count nor any amount of
//! transport recovery (retransmission, reconnect, shard reassignment)
//! can fork the bits. The adversarial matrix stages worker death
//! mid-run, seeded transport sabotage (garbled/truncated/dropped/
//! stalled frames, disconnects), reconnect-after-backoff, and
//! kill-and-resume from a coordinator auto-checkpoint.
//!
//! Fault schedules are pure in `(seed, worker, round, attempt)`
//! ([`TransportFaultPlan::decide`]), so tests *pre-scan* seeds for the
//! schedule shape they need (injections present, no accidental death)
//! instead of hoping — every run here is deterministic.

use mpbcfw::coordinator::checkpoint::load_run;
use mpbcfw::coordinator::distributed::protocol::Msg;
use mpbcfw::coordinator::distributed::transport::{TransportFaultKind, TransportFaultPlan};
use mpbcfw::coordinator::distributed::{
    resume_loopback, run_loopback, run_loopback_with_quits, serve_worker, Cluster, DistConfig,
    DistMode, TransportFaultConfig, WorkerConfig,
};
use mpbcfw::coordinator::faults::{FaultConfig, FaultMode, FaultPlan, FaultStats};
use mpbcfw::coordinator::metrics::Series;
use mpbcfw::coordinator::mp_bcfw::{self, MpBcfwConfig};
use mpbcfw::coordinator::parallel::{exact_pass, ExactPassExec};
use mpbcfw::coordinator::trainer::{self, DatasetKind, TrainSpec};
use mpbcfw::data::types::Scale;
use mpbcfw::model::problem::StructuredProblem as _;
use mpbcfw::oracle::wrappers::CountingOracle;
use mpbcfw::runtime::engine::NativeEngine;

fn problem(ds: DatasetKind) -> CountingOracle {
    trainer::build_problem(&TrainSpec { dataset: ds, scale: Scale::Tiny, ..Default::default() })
}

/// Pinned base config: `auto_approx` off (the §3.4 rule is
/// wall-clock-driven and would fork twin trajectories), fixed
/// approximate-pass budget, as in the fault-tolerance suite.
fn base_cfg(max_iters: u64, n: usize) -> MpBcfwConfig {
    MpBcfwConfig {
        max_iters,
        auto_approx: false,
        max_approx_passes: 2,
        threads: 2,
        seed: 7,
        ..MpBcfwConfig::mp_paper(1.0 / n as f64)
    }
}

/// Test-speed cluster shape: tight real-time timeouts so staged deaths
/// and reconnects resolve in fractions of a second.
fn fast_dist(workers: usize) -> DistConfig {
    DistConfig {
        mode: DistMode::Loopback,
        workers,
        straggler_timeout_s: 0.5,
        backoff_base_s: 0.005,
        ..DistConfig::default()
    }
}

/// Trajectory identity: (outer, dual bits, primal bits, exact-oracle
/// calls) per evaluation point. Timing columns are wall-clock-derived
/// and excluded.
fn bits(s: &Series) -> Vec<(u64, u64, u64, u64)> {
    s.points
        .iter()
        .map(|p| (p.outer, p.dual.to_bits(), p.primal.to_bits(), p.oracle_calls))
        .collect()
}

fn assert_monotone(s: &Series, label: &str) {
    for p in &s.points {
        assert!(p.primal >= p.dual - 1e-8, "{label}: weak duality violated at {p:?}");
    }
    for w in s.points.windows(2) {
        assert!(
            w[1].dual >= w[0].dual - 1e-10,
            "{label}: dual decreased {} -> {}",
            w[0].dual,
            w[1].dual
        );
    }
}

fn inject_transport(seed: u64, rate: f64) -> TransportFaultConfig {
    TransportFaultConfig { mode: FaultMode::Inject, seed, rate, window: None }
}

/// Model one run against the pure schedule: a worker dies in `(worker,
/// round)` iff every attempt `0..=retries` draws an injection (each
/// failed attempt — Soft or Dead — consumes exactly one attempt and the
/// worker survives to serve the resend). Returns (any attempt-0
/// injection, any cell that would kill its worker).
fn schedule_shape(
    t: &TransportFaultConfig,
    workers: u64,
    rounds: u64,
    retries: u64,
) -> (bool, bool) {
    let plan = TransportFaultPlan::from_config(t);
    let mut any = false;
    let mut death = false;
    for k in 0..workers {
        for r in 1..=rounds {
            any |= plan.decide(k, r, 0).is_some();
            death |= (0..=retries).all(|a| plan.decide(k, r, a).is_some());
        }
    }
    (any, death)
}

/// Smallest seed whose schedule injects at least once but never
/// exhausts a retry budget — sabotage with guaranteed survival.
fn survivable_seed(rate: f64, workers: u64, rounds: u64, retries: u64) -> u64 {
    (0..10_000)
        .find(|&seed| {
            let (any, death) =
                schedule_shape(&inject_transport(seed, rate), workers, rounds, retries);
            any && !death
        })
        .expect("no survivable transport seed in 0..10000; loosen the shape")
}

#[test]
fn loopback_cluster_is_bitwise_identical_to_single_process() {
    // The anchor on the two costly-oracle datasets (the paper's regime):
    // Viterbi sequences and graph-cut segmentation.
    for ds in [DatasetKind::OcrLike, DatasetKind::HorsesegLike] {
        let single = {
            let p = problem(ds);
            let mut eng = NativeEngine;
            let (s, _) = mp_bcfw::run(&p, &mut eng, &base_cfg(4, p.n()));
            s
        };
        for workers in [2usize, 3] {
            let p = problem(ds);
            let mut eng = NativeEngine;
            let (s, _) = run_loopback(&p, &mut eng, &base_cfg(4, p.n()), &fast_dist(workers))
                .expect("loopback run failed");
            assert_eq!(
                bits(&s),
                bits(&single),
                "{}: {workers}-worker cluster forked the single-process trajectory",
                ds.name()
            );
            assert_eq!(s.dist, "loopback");
            assert_eq!(s.dist_workers, workers as u64);
            assert_eq!(s.transport_faults, "off");
            assert_eq!(s.transport_retries, 0, "faults off must never retry");
            assert_eq!(s.worker_deaths, 0);
        }
    }
}

#[test]
fn staged_worker_death_reassigns_the_shard_and_preserves_the_trajectory() {
    let ds = DatasetKind::UspsLike;
    let single = {
        let p = problem(ds);
        let mut eng = NativeEngine;
        let (s, _) = mp_bcfw::run(&p, &mut eng, &base_cfg(4, p.n()));
        s
    };
    // Worker 1 serves exactly one round, then vanishes like a killed
    // process. Its residue class must be reassigned to worker 0 — whose
    // planes are bitwise the ones worker 1 would have produced, so the
    // run must complete on the anchor trajectory, deaths and all.
    let p = problem(ds);
    let mut eng = NativeEngine;
    let (s, _) = run_loopback_with_quits(
        &p,
        &mut eng,
        &base_cfg(4, p.n()),
        &fast_dist(2),
        &[None, Some(1)],
    )
    .expect("loopback run with staged death failed");
    assert_monotone(&s, "staged death");
    assert_eq!(s.worker_deaths, 1, "the staged quit was never detected");
    assert!(s.reassigned_blocks > 0, "the dead worker's shard was never reassigned");
    assert!(s.transport_retries > 0, "death detection must burn receive retries");
    assert_eq!(
        bits(&s),
        bits(&single),
        "shard reassignment forked the trajectory — planes are pure in (block, w)"
    );
}

#[test]
fn transport_sabotage_twins_are_bitwise_and_match_the_clean_anchor() {
    let ds = DatasetKind::UspsLike;
    let retries = fast_dist(2).reconnect_retries;
    let seed = survivable_seed(0.5, 2, 4, retries);
    let single = {
        let p = problem(ds);
        let mut eng = NativeEngine;
        let (s, _) = mp_bcfw::run(&p, &mut eng, &base_cfg(4, p.n()));
        s
    };
    let run_sabotaged = || {
        let p = problem(ds);
        let mut eng = NativeEngine;
        let dist = DistConfig { transport: inject_transport(seed, 0.5), ..fast_dist(2) };
        let (s, _) = run_loopback(&p, &mut eng, &base_cfg(4, p.n()), &dist)
            .expect("sabotaged loopback run failed");
        s
    };
    let a = run_sabotaged();
    let b = run_sabotaged();
    assert_eq!(a.transport_faults, "inject");
    assert!(a.transport_retries > 0, "scanned seed injected nothing");
    assert_eq!(a.worker_deaths, 0, "scanned seed promised survival");
    // Twin determinism: the schedule is pure, so both the trajectory
    // and the recovery counters replay identically.
    assert_eq!(bits(&a), bits(&b), "same-seed sabotage twins diverged");
    assert_eq!(
        (a.transport_retries, a.worker_deaths, a.reassigned_blocks),
        (b.transport_retries, b.worker_deaths, b.reassigned_blocks),
        "twins drew different recovery schedules"
    );
    // Trajectory transparency: every retry is a verbatim retransmission
    // of a plane that is pure in (block, snapshot-w) — sabotage without
    // death cannot fork the bits, and the shared in-process oracle
    // ledger proves no call was ever recomputed.
    assert_eq!(
        bits(&a),
        bits(&single),
        "recovered sabotage forked the trajectory (retransmission recomputed something?)"
    );
}

#[test]
fn every_transport_fault_kind_recovers_at_the_framing_boundary() {
    // Direct cluster drive with a schedule pre-scanned to contain all
    // five kinds at attempt 0 and kill nobody: each kind must land in
    // its stats counter and every round's planes must stay bitwise
    // equal to the in-process reference.
    let rounds = 6u64;
    let retries = 4u64;
    let seed = (0..20_000)
        .find(|&seed| {
            let t = inject_transport(seed, 0.5);
            let plan = TransportFaultPlan::from_config(&t);
            let (_, death) = schedule_shape(&t, 2, rounds, retries);
            let mut kinds = [false; 5];
            for k in 0..2 {
                for r in 1..=rounds {
                    if let Some(kind) = plan.decide(k, r, 0) {
                        kinds[match kind {
                            TransportFaultKind::Garble => 0,
                            TransportFaultKind::Truncate => 1,
                            TransportFaultKind::Drop => 2,
                            TransportFaultKind::Stall => 3,
                            TransportFaultKind::Disconnect => 4,
                        }] = true;
                    }
                }
            }
            kinds.iter().all(|&k| k) && !death
        })
        .expect("no seed covers all five fault kinds without a death; widen the scan");

    let p = problem(DatasetKind::UspsLike);
    let dist = DistConfig {
        transport: inject_transport(seed, 0.5),
        reconnect_retries: retries,
        ..fast_dist(2)
    };
    let w = vec![0.0f64; p.dim()];
    let order: Vec<usize> = (0..p.n()).collect();
    let no_oracle_faults = FaultPlan::from_config(&FaultConfig::default());
    let (reference, _) = exact_pass(&p, &w, &order, 1);

    let mut cluster =
        Cluster::bind(&p, &dist, "127.0.0.1:0", false).expect("bind failed");
    let addr = cluster.local_addr().unwrap();
    let stats = std::thread::scope(|s| {
        for k in 0..2u64 {
            let mut wcfg = WorkerConfig::for_dist(k, &dist, &FaultConfig::default());
            // The reference pass below uses cold arenas per call; pin
            // the workers to the same so the comparison is exact.
            wcfg.oracle_reuse = false;
            // Exercise the coordinator's bounded heartbeat tolerance on
            // every reply while we're at it.
            wcfg.heartbeats_per_round = 2;
            let p = &p;
            s.spawn(move || serve_worker(p, &wcfg, addr));
        }
        cluster.accept_workers().expect("workers never connected");
        for round in 1..=rounds {
            let (planes, report) = cluster.pass(&w, &order, round, &no_oracle_faults);
            assert_eq!(planes.len(), order.len());
            for ((&b, got), want) in order.iter().zip(&planes).zip(&reference) {
                let got = got.as_ref().unwrap_or_else(|| {
                    panic!("round {round}: block {b} lost despite a survivable schedule")
                });
                assert_eq!(got.tag, want.tag, "round {round}: block {b} plane diverged");
                assert_eq!(got.off, want.off, "round {round}: block {b} offset diverged");
            }
            assert_eq!(report.shard_secs.len(), 2);
        }
        cluster.shutdown();
        cluster.stats.clone()
    });
    assert!(stats.garbled >= 1, "Garble never exercised the checksum path");
    assert!(stats.truncated >= 1, "Truncate never exercised the short-read path");
    assert!(stats.dropped >= 1, "Drop never exercised the resend path");
    assert!(stats.stalled >= 1, "Stall never exercised the straggler path");
    assert!(stats.disconnects >= 1, "Disconnect never severed a link");
    assert!(stats.reconnects >= 1, "a severed link was never rebuilt");
    assert!(stats.retries >= 5, "five kinds must cost at least five retries");
    assert_eq!(stats.worker_deaths, 0, "scanned seed promised survival");
    assert_eq!(stats.lost_blocks, 0, "recovery must not lose blocks");
}

#[test]
fn cluster_kill_and_resume_matches_the_uninterrupted_tail() {
    let ds = DatasetKind::UspsLike;
    let full_cfg = {
        let p = problem(ds);
        base_cfg(8, p.n())
    };
    // Reference: one uninterrupted loopback run.
    let full = {
        let p = problem(ds);
        let mut eng = NativeEngine;
        let (s, _) = run_loopback(&p, &mut eng, &full_cfg, &fast_dist(2)).expect("full run");
        s
    };
    // "Killed" cluster: coordinator auto-checkpoints every 2 outers,
    // stops at 4 — the last atomic write stands in for killing every
    // process in the cluster.
    let path =
        std::env::temp_dir().join(format!("mpbcfw_it_dist_resume_{}", std::process::id()));
    let killed_cfg = MpBcfwConfig {
        max_iters: 4,
        faults: FaultConfig {
            checkpoint_every: 2,
            checkpoint_path: path.to_string_lossy().into_owned(),
            ..full_cfg.faults.clone()
        },
        ..full_cfg.clone()
    };
    let p = problem(ds);
    let mut eng = NativeEngine;
    let (killed, _) =
        run_loopback(&p, &mut eng, &killed_cfg, &fast_dist(2)).expect("killed run");
    assert!(path.is_file(), "coordinator auto-checkpoint never written");
    let full_bits = bits(&full);
    assert_eq!(bits(&killed), full_bits[..bits(&killed).len()].to_vec());

    // Resume on a *fresh* cluster: new problem, new workers, cold
    // arenas — value-neutral, like any resume.
    let fresh = problem(ds);
    let mut reloaded = load_run(&path, &fresh, &full_cfg).expect("load_run failed");
    assert_eq!(reloaded.outers_done, 4);
    let resumed = resume_loopback(&fresh, &mut eng, &full_cfg, &fast_dist(2), &mut reloaded)
        .expect("resume_loopback failed");
    std::fs::remove_file(&path).ok();
    let resumed_bits = bits(&resumed);
    let full_tail: Vec<_> = full_bits.into_iter().filter(|&(outer, ..)| outer >= 5).collect();
    assert_eq!(
        resumed_bits, full_tail,
        "resumed cluster diverged from the uninterrupted eval tail"
    );
}

#[test]
fn corrupt_frames_die_with_byte_offset_errors() {
    // The crash-safety contract of the wire codec, end to end at the
    // message level: truncation and bit flips must be *diagnosed*, not
    // decoded — truncation with the read position, flips by checksum.
    let msg = Msg::Planes {
        round: 3,
        worker: 1,
        planes: vec![(0, None), (7, None)],
        calls_total: 42,
        shard_secs: 0.5,
        fault_delta: FaultStats::default(),
        penalty_secs: 0.0,
    };
    let payload = msg.encode();
    let back = Msg::decode(&payload).expect("clean payload must decode");
    assert!(matches!(back, Msg::Planes { round: 3, worker: 1, .. }));
    for cut in [1, payload.len() / 2, payload.len() - 1] {
        let err = Msg::decode(&payload[..cut]).expect_err("truncated payload decoded");
        let text = err.to_string();
        // Either a short read (named by position) or the element-count
        // OOM guard (named by what was left) — never a silent decode.
        assert!(
            text.contains("byte offset") || text.contains("left in the frame"),
            "truncation at {cut} was not diagnosed by position: {text}"
        );
    }
}

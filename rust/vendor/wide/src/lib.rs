//! Minimal offline stand-in for a portable-SIMD crate (`wide`-style).
//!
//! The image this repo builds in has no crates.io access, so a real SIMD
//! crate cannot be fetched. This shim provides exactly the surface the
//! workspace's kernel layer uses: a 4-lane `f64` vector type with
//! elementwise arithmetic and an explicitly ordered horizontal sum.
//!
//! ## Lane contract
//!
//! [`f64x4`] is a `#[repr(C, align(32))]` newtype over `[f64; 4]`. Every
//! arithmetic op is written as four independent per-lane IEEE-754
//! operations — no fused multiply-add, no reassociation *within* a lane,
//! no architecture intrinsics. On x86-64 the fixed-width lane loops
//! compile to packed SSE2/AVX instructions under `-O` (the alignment
//! attribute plus the constant trip count make the vectorization
//! trivial for LLVM); on any other target the same code runs as four
//! scalar ops per call. Either way each lane performs the *identical*
//! IEEE operation, so lane results are bitwise stable across targets —
//! the portable "scalar fallback" is the same source code.
//!
//! Two consequences the kernel layer builds on:
//!
//! * **Elementwise use is bitwise-neutral.** A kernel that loads lanes,
//!   combines them elementwise, and stores them back (axpy-style)
//!   performs exactly the per-index arithmetic of the scalar loop, in
//!   the same order per index — results are bitwise identical to scalar.
//! * **Horizontal reduction reassociates.** [`f64x4::reduce_add`] folds
//!   the four lane accumulators in the fixed order `((l0+l1)+(l2+l3))`.
//!   A dot product that accumulates into four lanes and folds once at
//!   the end computes a *different* (equally valid) floating-point sum
//!   than the strict index-order scalar loop. Reduction kernels built on
//!   this type therefore carry a tolerance/drift contract, never a
//!   bitwise one. The fold order itself is fixed, so SIMD runs are
//!   deterministic and twin-reproducible — just not scalar-bitwise.

use std::ops::{Add, AddAssign, Mul, Sub};

/// Four f64 lanes with elementwise ops and a fixed-order horizontal sum.
///
/// See the crate docs for the lane contract (no FMA, no intra-lane
/// reassociation, deterministic fold order).
#[derive(Clone, Copy, Debug, PartialEq)]
#[repr(C, align(32))]
#[allow(non_camel_case_types)]
pub struct f64x4([f64; 4]);

impl f64x4 {
    /// Number of lanes.
    pub const LANES: usize = 4;

    /// All-zero lanes.
    pub const ZERO: f64x4 = f64x4([0.0; 4]);

    /// Build from an explicit lane array.
    #[inline(always)]
    pub fn new(lanes: [f64; 4]) -> f64x4 {
        f64x4(lanes)
    }

    /// Broadcast one value to all lanes.
    #[inline(always)]
    pub fn splat(v: f64) -> f64x4 {
        f64x4([v; 4])
    }

    /// Load lanes from the first four elements of a slice.
    ///
    /// Panics (via the indexing) when `s.len() < 4`; the kernel layer
    /// only calls this on `chunks_exact(4)` output.
    #[inline(always)]
    pub fn from_slice(s: &[f64]) -> f64x4 {
        f64x4([s[0], s[1], s[2], s[3]])
    }

    /// Store lanes into the first four elements of a slice.
    #[inline(always)]
    pub fn write_to_slice(self, out: &mut [f64]) {
        out[0] = self.0[0];
        out[1] = self.0[1];
        out[2] = self.0[2];
        out[3] = self.0[3];
    }

    /// The lane array by value.
    #[inline(always)]
    pub fn to_array(self) -> [f64; 4] {
        self.0
    }

    /// Horizontal sum in the fixed order `(l0 + l1) + (l2 + l3)`.
    ///
    /// This is the *only* place the type combines values across lanes.
    /// The pairwise order is pinned (not left-to-right) because it is
    /// what a hardware `haddpd`/shuffle reduction produces and it keeps
    /// the two halves symmetric; what matters for the determinism
    /// contract is that the order is fixed, not which fixed order.
    #[inline(always)]
    pub fn reduce_add(self) -> f64 {
        (self.0[0] + self.0[1]) + (self.0[2] + self.0[3])
    }
}

impl Add for f64x4 {
    type Output = f64x4;
    #[inline(always)]
    fn add(self, rhs: f64x4) -> f64x4 {
        f64x4([
            self.0[0] + rhs.0[0],
            self.0[1] + rhs.0[1],
            self.0[2] + rhs.0[2],
            self.0[3] + rhs.0[3],
        ])
    }
}

impl AddAssign for f64x4 {
    #[inline(always)]
    fn add_assign(&mut self, rhs: f64x4) {
        *self = *self + rhs;
    }
}

impl Sub for f64x4 {
    type Output = f64x4;
    #[inline(always)]
    fn sub(self, rhs: f64x4) -> f64x4 {
        f64x4([
            self.0[0] - rhs.0[0],
            self.0[1] - rhs.0[1],
            self.0[2] - rhs.0[2],
            self.0[3] - rhs.0[3],
        ])
    }
}

impl Mul for f64x4 {
    type Output = f64x4;
    #[inline(always)]
    fn mul(self, rhs: f64x4) -> f64x4 {
        f64x4([
            self.0[0] * rhs.0[0],
            self.0[1] * rhs.0[1],
            self.0[2] * rhs.0[2],
            self.0[3] * rhs.0[3],
        ])
    }
}

impl Mul<f64> for f64x4 {
    type Output = f64x4;
    #[inline(always)]
    fn mul(self, rhs: f64) -> f64x4 {
        self * f64x4::splat(rhs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elementwise_ops_are_per_lane_ieee() {
        let a = f64x4::new([1.0, -2.0, 0.5, 1e300]);
        let b = f64x4::new([3.0, 0.25, -0.5, 1e300]);
        let s = (a + b).to_array();
        let p = (a * b).to_array();
        let d = (a - b).to_array();
        for k in 0..4 {
            assert_eq!(s[k].to_bits(), (a.to_array()[k] + b.to_array()[k]).to_bits());
            assert_eq!(p[k].to_bits(), (a.to_array()[k] * b.to_array()[k]).to_bits());
            assert_eq!(d[k].to_bits(), (a.to_array()[k] - b.to_array()[k]).to_bits());
        }
    }

    #[test]
    fn reduce_add_order_is_pinned() {
        // Values chosen so every association order gives a different
        // float: the pinned order must match the documented expression
        // exactly, and (for these values) differ from left-to-right.
        let v = [1e16, 1.0, -1e16, 1.0];
        let x = f64x4::new(v);
        let pinned = (v[0] + v[1]) + (v[2] + v[3]);
        assert_eq!(x.reduce_add().to_bits(), pinned.to_bits());
        let ltr = ((v[0] + v[1]) + v[2]) + v[3];
        assert_ne!(pinned.to_bits(), ltr.to_bits(), "test values too tame");
    }

    #[test]
    fn splat_slice_round_trip() {
        assert_eq!(f64x4::splat(2.5).to_array(), [2.5; 4]);
        let s = [9.0, 8.0, 7.0, 6.0, 5.0];
        let x = f64x4::from_slice(&s);
        assert_eq!(x.to_array(), [9.0, 8.0, 7.0, 6.0]);
        let mut out = [0.0; 4];
        x.write_to_slice(&mut out);
        assert_eq!(out, [9.0, 8.0, 7.0, 6.0]);
        let mut acc = f64x4::ZERO;
        acc += x;
        assert_eq!(acc, x);
        assert_eq!((x * 2.0).to_array(), [18.0, 16.0, 14.0, 12.0]);
    }
}

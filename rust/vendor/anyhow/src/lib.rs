//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The image this repo builds in has no crates.io access, so the real
//! `anyhow` cannot be fetched. This shim provides exactly the surface the
//! workspace uses — [`Error`], [`Result`], and the `anyhow!` / `bail!` /
//! `ensure!` macros — with the same semantics for that subset:
//!
//!  * `Error` is an opaque, `Display`-able error value;
//!  * any `std::error::Error` converts into it via `?`;
//!  * `Error` deliberately does **not** implement `std::error::Error`
//!    itself (exactly like the real crate), which is what makes the
//!    blanket `From` impl coherent.
//!
//! Context chaining (`.context(...)`), backtraces and downcasting are not
//! implemented; nothing in this workspace uses them.

use std::fmt;

/// An opaque error value carrying a human-readable message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// The real anyhow's signature modulo backtraces: every standard error
// converts. Coherent only because `Error` itself is not a
// `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let v: u32 = s.parse()?; // std error converts via From
        ensure!(v > 0, "value must be positive, got {v}");
        Ok(v)
    }

    #[test]
    fn question_mark_and_ensure() {
        assert_eq!(parse("3").unwrap(), 3);
        assert!(parse("x").is_err());
        assert_eq!(parse("0").unwrap_err().to_string(), "value must be positive, got 0");
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain literal");
        assert_eq!(a.to_string(), "plain literal");
        let x = 7;
        let b = anyhow!("captured {x}");
        assert_eq!(b.to_string(), "captured 7");
        let c = anyhow!("fmt {} and {}", 1, 2);
        assert_eq!(c.to_string(), "fmt 1 and 2");
        let msg = String::from("from a value");
        let d = anyhow!(msg);
        assert_eq!(d.to_string(), "from a value");
    }

    #[test]
    fn bail_returns_error() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("flag was {flag}");
            }
            Ok(())
        }
        assert!(f(false).is_ok());
        assert_eq!(f(true).unwrap_err().to_string(), "flag was true");
    }

    #[test]
    fn ensure_without_message() {
        fn f(v: i32) -> Result<i32> {
            ensure!(v % 2 == 0);
            Ok(v)
        }
        assert!(f(2).is_ok());
        assert!(f(3).unwrap_err().to_string().contains("condition failed"));
    }
}

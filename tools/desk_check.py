#!/usr/bin/env python3
"""Static desk checks for the Rust tree (no toolchain required).

Two checks, both string/comment-aware:

1. **Balance**: every `.rs` file must have balanced `{}`, `()`, `[]`
   outside of strings, char literals, and comments. Catches truncated
   files, mismatched edits, and macro bodies cut mid-way.

2. **Struct-literal exhaustiveness**: every literal of the structs
   listed in ``CHECKED_STRUCTS`` must either initialize all declared
   fields or use functional-update syntax (``..``). Catches the classic
   "added a field to EvalPoint, missed one constructor" compile error
   before a compiler ever sees the code.

Exit status is non-zero on any finding. Run from anywhere:

    python3 tools/desk_check.py [repo_root]
"""

import re
import sys
from pathlib import Path

# (struct name, file that declares it). Extend as structs grow fields.
CHECKED_STRUCTS = [
    ("EvalPoint", "rust/src/coordinator/metrics.rs"),
    ("TrainSpec", "rust/src/coordinator/trainer.rs"),
    ("MpBcfwConfig", "rust/src/coordinator/mp_bcfw.rs"),
    ("AsyncStats", "rust/src/coordinator/async_overlap.rs"),
    ("ProductStats", "rust/src/coordinator/products.rs"),
    ("BaselineProvenance", "rust/src/bench/regress.rs"),
    ("BaselineCounters", "rust/src/bench/regress.rs"),
    ("Baseline", "rust/src/bench/regress.rs"),
    ("Measured", "rust/src/bench/regress.rs"),
    ("GoldenFixture", "rust/tests/golden_trajectory.rs"),
    ("FaultPlan", "rust/src/coordinator/faults.rs"),
    ("FaultStats", "rust/src/coordinator/faults.rs"),
    ("DistConfig", "rust/src/coordinator/distributed/mod.rs"),
    ("TransportFaultConfig", "rust/src/coordinator/distributed/transport.rs"),
    ("TransportStats", "rust/src/coordinator/distributed/transport.rs"),
]

OPEN = {"{": "}", "(": ")", "[": "]"}
CLOSE = {v: k for k, v in OPEN.items()}


def strip_code(text):
    """Return `text` with comments/strings/chars blanked (newlines kept),
    so bracket scanning and struct-literal parsing see only real code."""
    out = []
    i, n = 0, len(text)
    while i < n:
        c = text[i]
        nxt = text[i + 1] if i + 1 < n else ""
        if c == "/" and nxt == "/":
            while i < n and text[i] != "\n":
                i += 1
            continue
        if c == "/" and nxt == "*":
            depth = 1
            i += 2
            while i < n and depth:
                if text[i] == "/" and i + 1 < n and text[i + 1] == "*":
                    depth += 1
                    i += 2
                elif text[i] == "*" and i + 1 < n and text[i + 1] == "/":
                    depth -= 1
                    i += 2
                else:
                    if text[i] == "\n":
                        out.append("\n")
                    i += 1
            continue
        if c == "r" and nxt in "\"#":
            # Raw string r"..." / r#"..."#
            j = i + 1
            hashes = 0
            while j < n and text[j] == "#":
                hashes += 1
                j += 1
            if j < n and text[j] == '"':
                end = text.find('"' + "#" * hashes, j + 1)
                if end == -1:
                    break
                segment = text[i : end + 1 + hashes]
                out.append("\n" * segment.count("\n"))
                i = end + 1 + hashes
                continue
        if c == '"':
            i += 1
            while i < n:
                if text[i] == "\\":
                    i += 2
                    continue
                if text[i] == '"':
                    i += 1
                    break
                if text[i] == "\n":
                    out.append("\n")
                i += 1
            continue
        if c == "'":
            # Char literal vs lifetime: a char literal closes within a
            # couple of characters ('x', '\n', '\u{1F600}').
            m = re.match(r"'(\\u\{[0-9a-fA-F]{1,6}\}|\\.|[^\\'])'", text[i:])
            if m:
                i += m.end()
                continue
            i += 1  # lifetime tick: skip the quote only
            continue
        out.append(c)
        i += 1
    return "".join(out)


def check_balance(path, code):
    stack = []
    line = 1
    for ch in code:
        if ch == "\n":
            line += 1
        elif ch in OPEN:
            stack.append((ch, line))
        elif ch in CLOSE:
            if not stack or stack[-1][0] != CLOSE[ch]:
                return [f"{path}:{line}: unmatched '{ch}'"]
            stack.pop()
    return [f"{path}:{l}: unclosed '{c}'" for c, l in stack]


def struct_fields(code, name):
    """Field names of `pub struct <name> { ... }` in stripped code."""
    m = re.search(r"pub struct %s\s*\{" % re.escape(name), code)
    if not m:
        return None
    i = m.end()
    depth = 1
    body = []
    while i < len(code) and depth:
        if code[i] == "{":
            depth += 1
        elif code[i] == "}":
            depth -= 1
        if depth:
            body.append(code[i])
        i += 1
    fields = []
    for fm in re.finditer(r"(?:pub\s+)?([a-z_][a-z0-9_]*)\s*:", "".join(body)):
        fields.append(fm.group(1))
    return fields


def check_literals(path, code, name, fields):
    """Every `Name { ... }` literal must set all fields or use `..`."""
    findings = []
    for m in re.finditer(r"\b%s\s*\{" % re.escape(name), code):
        # Skip the declaration itself, impl blocks, and return types
        # (`fn f(...) -> Name {` opens a body, not a literal).
        prefix = code[max(0, m.start() - 80) : m.start()].rstrip()
        if re.search(r"(struct|impl|for|trait)$", prefix):
            continue
        if prefix.endswith("->"):
            continue
        i = m.end()
        depth = 1
        body = []
        while i < len(code) and depth:
            if code[i] == "{":
                depth += 1
            elif code[i] == "}":
                depth -= 1
            if depth:
                body.append(code[i])
            i += 1
        body = "".join(body)
        line = code[: m.start()].count("\n") + 1
        if ".." in body:
            continue  # functional update / rest pattern
        # Split the body on top-level commas; each segment starts with a
        # field name (`name: expr` or shorthand `name`).
        segments, seg, d = [], [], 0
        for ch in body:
            if ch in "{([":
                d += 1
            elif ch in "})]":
                d -= 1
            if ch == "," and d == 0:
                segments.append("".join(seg))
                seg = []
            else:
                seg.append(ch)
        segments.append("".join(seg))
        present = set()
        for s in segments:
            fm = re.match(r"\s*([a-z_][a-z0-9_]*)\s*(?::|$)", s)
            if fm:
                present.add(fm.group(1))
        missing = [f for f in fields if f not in present]
        if missing:
            findings.append(
                f"{path}:{line}: {name} literal missing fields: {', '.join(missing)}"
            )
    return findings


def main():
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).resolve().parents[1]
    rs_files = sorted((root / "rust").rglob("*.rs")) + sorted(
        (root / "examples").glob("*.rs")
    )
    findings = []
    stripped = {}
    for p in rs_files:
        code = strip_code(p.read_text())
        stripped[p] = code
        findings += check_balance(p.relative_to(root), code)

    for name, decl in CHECKED_STRUCTS:
        decl_path = root / decl
        fields = struct_fields(stripped[decl_path], name)
        if not fields:
            findings.append(f"{decl}: could not parse struct {name}")
            continue
        for p, code in stripped.items():
            findings += check_literals(p.relative_to(root), code, name, fields)

    if findings:
        print(f"desk_check: {len(findings)} finding(s)")
        for f in findings:
            print("  " + f)
        return 1
    print(
        f"desk_check: OK ({len(rs_files)} files balanced; "
        f"{', '.join(n for n, _ in CHECKED_STRUCTS)} literals exhaustive)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())

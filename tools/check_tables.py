#!/usr/bin/env python3
"""Bench-table gate: parse a bench CSV and fail if any row's value in
the named column is `false`, printing the offending rows.

Replaces the fragile `! grep -q false table.csv` CI checks, which (a)
could trip on `false` anywhere in the file — a dataset name, a float's
digits after a format change — and (b) could not say which row broke.
An empty or header-only table also fails (subsumes `test -s`): a sweep
that silently produced nothing must not read as green.

    python3 tools/check_tables.py results/table_products.csv matches_baseline

Empty cells are allowed — some tables leave the bitwise column blank on
rows that make no claim (e.g. the reference row itself).
"""

import csv
import sys


def main():
    if len(sys.argv) != 3:
        print("usage: check_tables.py <table.csv> <column>")
        return 2
    path, column = sys.argv[1], sys.argv[2]
    try:
        with open(path, newline="") as fh:
            rows = list(csv.reader(fh))
    except OSError as e:
        print(f"check_tables: {path}: {e}")
        return 1
    if not rows:
        print(f"check_tables: {path}: empty file")
        return 1
    header, data = rows[0], rows[1:]
    if column not in header:
        print(f"check_tables: {path}: no column '{column}' (have: {', '.join(header)})")
        return 1
    col = header.index(column)
    if not data:
        print(f"check_tables: {path}: header only, no data rows")
        return 1
    bad = [
        (line_no, row)
        for line_no, row in enumerate(data, start=2)
        if len(row) > col and row[col].strip() == "false"
    ]
    if bad:
        print(f"check_tables: {path}: {len(bad)} row(s) failed the '{column}' check")
        for line_no, row in bad:
            cells = ", ".join(f"{h}={v}" for h, v in zip(header, row))
            print(f"  line {line_no}: {cells}")
        return 1
    print(f"check_tables: OK ({path}: {len(data)} rows, column '{column}' clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""AOT driver: lower the L2 graphs (with their L1 Pallas kernels) to HLO
text artifacts + a manifest the Rust runtime loads.

Run once via `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts

Python never runs after this; the Rust binary executes the artifacts
through PJRT. Interchange is HLO *text* (not serialized HloModuleProto):
jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects,
while the text parser reassigns ids cleanly (see /opt/xla-example).

Bucketed shapes: the runtime pads dynamic sizes (working-set rows,
sequence lengths, superpixel counts) up to the next bucket, so one
executable serves many request shapes. The bucket list below is curated
to cover every (dataset, scale) this repo ships; the Rust engine falls
back to the native path (and records a miss) for anything else.
"""

import argparse
import json
import os

import jax.numpy as jnp

from . import model

# (rows, cols) buckets for the scoring mat-vec (working-set scoring at
# cols = d+1; multiclass class scoring at rows = #classes).
MATVEC_BUCKETS = [
    (r, c)
    for r in (16, 64, 256, 1024)
    for c in (64, 256, 1024, 2048, 4096)
]

# Fused working-set argmax (same geometry as the mat-vec).
SELECT_BUCKETS = [
    (r, c) for r in (16, 64, 256) for c in (256, 1024, 2048, 4096)
]

# (m, k, n) buckets for the unary-score matmul a[M,K] @ b[N,K]^T, curated
# per dataset/scale: OCR tiny/small/paper, HorseSeg tiny/small/paper.
MATMUL_BT_BUCKETS = [
    (16, 16, 8),     # ocr tiny:   L<=6,  F=8,   A=6
    (16, 32, 32),    # ocr small:  L<=11, F=32,  A=26
    (16, 128, 32),   # ocr paper:  L<=11, F=128, A=26
    (64, 16, 2),     # horseseg tiny:  L<=36,  F=12
    (256, 64, 2),    # horseseg small: L<=144, F=64
    (512, 1024, 2),  # horseseg paper: L<=289, F=649
]

DTYPE = jnp.float32


def _spec(shape):
    return jnp.zeros(shape, DTYPE)


def build_entries():
    """Yield (name, file, meta, lower_fn) for every artifact."""
    entries = []
    for rows, cols in MATVEC_BUCKETS:
        name = f"plane_scores_r{rows}_c{cols}"
        entries.append(
            (
                name,
                {"op": "plane_scores", "rows": rows, "cols": cols},
                lambda rows=rows, cols=cols: model.lower_to_hlo_text(
                    model.plane_scores, _spec((rows, cols)), _spec((cols,))
                ),
            )
        )
    for rows, cols in SELECT_BUCKETS:
        name = f"approx_select_r{rows}_c{cols}"
        entries.append(
            (
                name,
                {"op": "approx_select", "rows": rows, "cols": cols},
                lambda rows=rows, cols=cols: model.lower_to_hlo_text(
                    model.approx_select,
                    _spec((rows, cols)),
                    _spec((rows,)),
                    _spec((rows,)),
                    _spec((cols,)),
                    _spec(()),
                ),
            )
        )
    for m, k, n in MATMUL_BT_BUCKETS:
        name = f"matmul_bt_m{m}_k{k}_n{n}"
        entries.append(
            (
                name,
                {"op": "matmul_bt", "m": m, "k": k, "n": n},
                lambda m=m, k=k, n=n: model.lower_to_hlo_text(
                    model.matmul_bt, _spec((m, k)), _spec((n, k))
                ),
            )
        )
    return entries


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--only", default=None, help="substring filter for artifact names (debug)"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"version": 1, "dtype": "f32", "ops": []}
    entries = build_entries()
    for name, meta, lower in entries:
        if args.only and args.only not in name:
            continue
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        text = lower()
        with open(path, "w") as f:
            f.write(text)
        meta = dict(meta)
        meta["file"] = fname
        manifest["ops"].append(meta)
        print(f"  wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"manifest: {len(manifest['ops'])} artifacts -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()

"""L2: JAX compute graphs for the MP-BCFW scoring hot spots.

These are the functions `python/compile/aot.py` lowers to HLO text for the
Rust runtime. Each one calls the L1 Pallas kernels so everything lowers
into a single HLO module per (op, bucket-shape) pair. Python never runs at
training time — the Rust coordinator executes these artifacts via PJRT.

Ops:
  * plane_scores(planes[N,D], v[D]) -> [N]
        working-set scoring (approximate oracle) and multiclass class
        scoring (rows = class weight blocks).
  * matmul_bt(a[M,K], b[N,K]) -> [M,N]
        unary score matrices for the Viterbi / graph-cut oracles.
  * approx_select(planes[N,D1], offs[N], mask[N], phi[D1], lam) ->
        (best_idx, best_score)
        fused working-set argmax at w = -phi/lam: one PJRT call returns
        the chosen plane index directly (saves shipping the score vector
        back on the hot path).
"""

import jax
import jax.numpy as jnp

from .kernels.matmul_bt import matmul_bt as _matmul_bt_kernel
from .kernels.plane_scores import plane_scores as _plane_scores_kernel


def plane_scores(planes, v):
    return _plane_scores_kernel(planes, v)


def matmul_bt(a, b):
    return _matmul_bt_kernel(a, b)


def approx_select(planes, offs, mask, phi, lam):
    """Fused approximate-oracle selection (§3.3).

    planes: [N, D] linear parts of the cached planes (padded rows zero),
    offs:   [N]   their offsets,
    mask:   [N]   1.0 for live rows, 0.0 for padding,
    phi:    [D]   current global phi_* (w = -phi/lam),
    lam:    []    regularization constant.

    Returns (best_idx int32, best_score f32) of
    argmax_j <p_j, [w 1]> = argmax_j -<p_j, phi>/lam + off_j over live rows.
    """
    dots = _plane_scores_kernel(planes, phi)  # [N]
    scores = -dots / lam + offs
    neg = jnp.finfo(scores.dtype).min
    scores = jnp.where(mask > 0.5, scores, neg)
    best = jnp.argmax(scores)
    return best.astype(jnp.int32), scores[best]


def lower_to_hlo_text(fn, *example_args) -> str:
    """Lower a jitted function to HLO *text* (the interchange format the
    xla 0.1.6 crate accepts — serialized protos from jax >= 0.5 carry
    64-bit instruction ids that xla_extension 0.5.1 rejects)."""
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()

"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the kernel tests (pytest + hypothesis) compare
against; they are also what the L2 graphs would use if the Pallas path
were disabled. Keep them boring and obviously correct.
"""

import jax.numpy as jnp


def plane_scores_ref(planes: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """scores[i] = <planes[i, :], v> — the working-set / class-scoring
    mat-vec. planes: [N, D], v: [D] -> [N]."""
    return planes @ v


def matmul_bt_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """out = a @ b.T with b stored row-major [N, K] (per-label weight
    blocks). a: [M, K], b: [N, K] -> [M, N]."""
    return a @ b.T


def loss_augment_ref(theta: jnp.ndarray, labels: jnp.ndarray, inv_len: float) -> jnp.ndarray:
    """Add (1/L)[a != y_l] to each unary score. theta: [L, A],
    labels: [L] int32 -> [L, A]."""
    L, A = theta.shape
    onehot = jnp.arange(A)[None, :] == labels[:, None]
    return theta + inv_len * (1.0 - onehot.astype(theta.dtype))

"""L1 Pallas kernel: scoring matmul with transposed weights.

out[M, N] = a[M, K] @ b[N, K]^T

Rows of `a` are items (sequence positions / superpixels), rows of `b` are
per-label weight blocks — the layout both the Viterbi and graph-cut
oracles use, so neither side needs a transpose copy. 3-D grid over
(M-blocks, N-blocks, K-blocks) with accumulation over K, the standard
MXU-shaped schedule (on TPU the inner tile would map to the 128x128
systolic array; under interpret=True we validate numerics on CPU).

VMEM per step (f32): BM*BK + BN*BK + BM*BN floats
    = (64*512 + 32*512 + 64*32) * 4 B ≈ 200 KiB at the defaults.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 64
BLOCK_N = 32
BLOCK_K = 512


def _kernel(a_ref, b_ref, out_ref):
    k_idx = pl.program_id(2)
    a_blk = a_ref[...]  # [BM, BK]
    b_blk = b_ref[...]  # [BN, BK]
    partial = a_blk @ b_blk.T  # [BM, BN]

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(k_idx != 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def matmul_bt(a, b, *, block_m=BLOCK_M, block_n=BLOCK_N, block_k=BLOCK_K):
    """out = a @ b.T via the Pallas kernel (interpret mode)."""
    m, k = a.shape
    n, k2 = b.shape
    assert k == k2, (k, k2)
    bm = min(block_m, m)
    bn = min(block_n, n)
    bk = min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bn, bk), lambda i, j, l: (j, l)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


def vmem_bytes(block_m=BLOCK_M, block_n=BLOCK_N, block_k=BLOCK_K, dtype_bytes=4):
    return dtype_bytes * (block_m * block_k + block_n * block_k + block_m * block_n)

"""L1 Pallas kernel: working-set scoring mat-vec.

scores[N] = planes[N, D] @ v[D]

This is the hot spot of MP-BCFW's approximate oracle (and, with
planes := per-class weight blocks, of the multiclass exact oracle). The
kernel tiles the plane matrix into (BN x BD) VMEM blocks on a 2-D grid and
accumulates partial dot products into the output block, which is the
HBM->VMEM schedule a TPU would want; `interpret=True` makes it run (and be
lowered to plain HLO) on the CPU PJRT backend — see DESIGN.md
§Hardware-Adaptation.

VMEM footprint per grid step (f32):
    BN*BD (planes tile) + BD (v tile) + BN (acc) floats
    = 128*512*4 B ≈ 256 KiB at the default blocks — comfortably within a
    TPU core's ~16 MiB VMEM, leaving room for double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block sizes (tuned for structure, not CPU wall-clock; see
# module docstring).
BLOCK_N = 128
BLOCK_D = 512


def _kernel(planes_ref, v_ref, out_ref):
    """One (BN, BD) tile: accumulate partial mat-vec into out tile."""
    d_idx = pl.program_id(1)
    block = planes_ref[...]  # [BN, BD]
    vseg = v_ref[...]  # [BD]
    partial = block @ vseg  # [BN]

    @pl.when(d_idx == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(d_idx != 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("block_n", "block_d"))
def plane_scores(planes, v, *, block_n=BLOCK_N, block_d=BLOCK_D):
    """scores = planes @ v via the Pallas kernel (interpret mode).

    Shapes must be multiples of the block sizes; the AOT wrapper pads to
    the bucket sizes, so this always holds on the artifact path.
    """
    n, d = planes.shape
    bn = min(block_n, n)
    bd = min(block_d, d)
    assert n % bn == 0 and d % bd == 0, (n, d, bn, bd)
    grid = (n // bn, d // bd)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), planes.dtype),
        interpret=True,
    )(planes, v)


def vmem_bytes(block_n=BLOCK_N, block_d=BLOCK_D, dtype_bytes=4):
    """Estimated VMEM footprint of one grid step (for DESIGN.md §Perf)."""
    return dtype_bytes * (block_n * block_d + block_d + block_n)

"""L1 kernel correctness: Pallas (interpret mode) vs pure-jnp reference,
swept over shapes and dtypes with hypothesis."""

import jax

# The dtype sweep below includes real float64; without x64 jax silently
# downcasts and the f64 tolerances are unreachable.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul_bt import matmul_bt, vmem_bytes as mm_vmem
from compile.kernels.plane_scores import plane_scores, vmem_bytes as ps_vmem
from compile.kernels.ref import loss_augment_ref, matmul_bt_ref, plane_scores_ref

RTOL = {np.float32: 2e-4, np.float64: 1e-10}
ATOL = {np.float32: 1e-4, np.float64: 1e-12}


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


# Shapes are powers of two times small factors so the block-divisibility
# contract holds (the AOT path always pads to bucket shapes).
pow2 = lambda lo, hi: st.sampled_from([2**i for i in range(lo, hi + 1)])


@settings(max_examples=25, deadline=None)
@given(n=pow2(0, 9), d=pow2(0, 11), seed=st.integers(0, 2**31), f64=st.booleans())
def test_plane_scores_matches_ref(n, d, seed, f64):
    dtype = np.float64 if f64 else np.float32
    planes = _rand((n, d), dtype, seed)
    v = _rand((d,), dtype, seed + 1)
    got = np.asarray(plane_scores(jnp.array(planes), jnp.array(v)))
    want = np.asarray(plane_scores_ref(jnp.array(planes), jnp.array(v)))
    np.testing.assert_allclose(got, want, rtol=RTOL[dtype], atol=ATOL[dtype] * d)


@settings(max_examples=25, deadline=None)
@given(
    m=pow2(0, 8),
    k=pow2(0, 10),
    n=pow2(0, 6),
    seed=st.integers(0, 2**31),
    f64=st.booleans(),
)
def test_matmul_bt_matches_ref(m, k, n, seed, f64):
    dtype = np.float64 if f64 else np.float32
    a = _rand((m, k), dtype, seed)
    b = _rand((n, k), dtype, seed + 1)
    got = np.asarray(matmul_bt(jnp.array(a), jnp.array(b)))
    want = np.asarray(matmul_bt_ref(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, want, rtol=RTOL[dtype], atol=ATOL[dtype] * k)


def test_plane_scores_zero_vector():
    planes = _rand((16, 64), np.float32, 0)
    out = np.asarray(plane_scores(jnp.array(planes), jnp.zeros(64, "float32")))
    np.testing.assert_array_equal(out, np.zeros(16, "float32"))


def test_plane_scores_identity_rows():
    # Row i = e_i picks out v[i].
    eye = np.eye(16, dtype=np.float32)
    v = _rand((16,), np.float32, 3)
    out = np.asarray(plane_scores(jnp.array(eye), jnp.array(v)))
    np.testing.assert_allclose(out, v, rtol=1e-6)


def test_matmul_bt_against_plane_scores_row():
    # matmul_bt with m=1 must agree with plane_scores on b as the matrix.
    a = _rand((1, 128), np.float32, 5)
    b = _rand((8, 128), np.float32, 6)
    mm = np.asarray(matmul_bt(jnp.array(a), jnp.array(b)))[0]
    ps = np.asarray(plane_scores(jnp.array(b), jnp.array(a[0])))
    np.testing.assert_allclose(mm, ps, rtol=2e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(l=st.integers(1, 12), a=st.integers(2, 26), seed=st.integers(0, 2**31))
def test_loss_augment_ref_properties(l, a, seed):
    theta = _rand((l, a), np.float32, seed)
    rng = np.random.default_rng(seed + 7)
    labels = rng.integers(0, a, size=l).astype(np.int32)
    out = np.asarray(loss_augment_ref(jnp.array(theta), jnp.array(labels), 1.0 / l))
    for i in range(l):
        for c in range(a):
            expect = theta[i, c] + (0.0 if c == labels[i] else 1.0 / l)
            assert abs(out[i, c] - expect) < 1e-6


def test_vmem_estimates_within_tpu_budget():
    # Default block shapes must fit a TPU core's VMEM with headroom for
    # double buffering (DESIGN.md hardware-adaptation contract).
    assert ps_vmem() * 2 < 16 * 2**20
    assert mm_vmem() * 2 < 16 * 2**20

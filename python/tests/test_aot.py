"""AOT artifact pipeline: manifest completeness, file integrity, and the
bucket-coverage contract with the Rust runtime."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_build_entries_unique_names():
    entries = aot.build_entries()
    names = [e[0] for e in entries]
    assert len(names) == len(set(names))
    assert len(names) >= 30


def test_buckets_cover_all_shipped_dataset_shapes():
    # (dataset, scale) -> required shapes; keep in sync with
    # rust/src/data/synth/*. A missing bucket silently falls back to the
    # native engine, which would defeat the parity tests.
    matvec_cols_needed = [
        160 + 1, 640 + 1, 2560 + 1,          # usps tiny/small/paper (dim+1)
        6 * 8 + 36 + 1, 26 * 32 + 676 + 1, 26 * 128 + 676 + 1,  # ocr
        24 + 1, 128 + 1, 1298 + 1,           # horseseg
    ]
    cols_avail = sorted({c for _, c in aot.MATVEC_BUCKETS})
    for need in matvec_cols_needed:
        assert any(c >= need for c in cols_avail), f"no matvec bucket for cols={need}"
    mm_needed = [
        (11, 8, 6), (11, 32, 26), (11, 128, 26),      # ocr tiny/small/paper
        (36, 12, 2), (144, 64, 2), (289, 649, 2),     # horseseg
    ]
    for m, k, n in mm_needed:
        ok = any(bm >= m and bk >= k and bn >= n for bm, bk, bn in aot.MATMUL_BT_BUCKETS)
        assert ok, f"no matmul_bt bucket for ({m},{k},{n})"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_matches_files_on_disk():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    assert manifest["dtype"] == "f32"
    for op in manifest["ops"]:
        path = os.path.join(ARTIFACTS, op["file"])
        assert os.path.exists(path), f"missing {op['file']}"
        with open(path) as g:
            head = g.read(64)
        assert head.startswith("HloModule"), f"{op['file']} is not HLO text"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built",
)
def test_artifacts_contain_no_custom_calls():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    for op in manifest["ops"]:
        with open(os.path.join(ARTIFACTS, op["file"])) as g:
            assert "custom-call" not in g.read(), op["file"]


def test_aot_only_filter(tmp_path):
    # --only lowers a single artifact quickly; sanity for the debug path.
    out = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out",
            str(tmp_path),
            "--only",
            "plane_scores_r16_c64",
        ],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert out.returncode == 0, out.stderr
    with open(tmp_path / "manifest.json") as f:
        manifest = json.load(f)
    assert len(manifest["ops"]) == 1
    assert (tmp_path / "plane_scores_r16_c64.hlo.txt").exists()

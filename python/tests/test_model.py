"""L2 graph correctness: the fused ops must agree with their unfused
reference math."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model


def _rand(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([1, 2, 4, 8, 16]),
    d=st.sampled_from([4, 64, 256]),
    seed=st.integers(0, 2**31),
)
def test_approx_select_matches_manual_argmax(n, d, seed):
    planes = _rand((n, d), seed)
    offs = _rand((n,), seed + 1)
    phi = _rand((d,), seed + 2)
    lam = 0.37
    mask = np.ones(n, np.float32)
    idx, score = model.approx_select(
        jnp.array(planes), jnp.array(offs), jnp.array(mask), jnp.array(phi), jnp.float32(lam)
    )
    scores = -(planes @ phi) / lam + offs
    assert int(idx) == int(np.argmax(scores))
    np.testing.assert_allclose(float(score), scores.max(), rtol=2e-4, atol=1e-4)


def test_approx_select_respects_mask():
    # The best row is masked out -> second best must win.
    planes = np.zeros((4, 8), np.float32)
    offs = np.array([1.0, 5.0, 3.0, 4.0], np.float32)
    phi = np.zeros(8, np.float32)
    mask = np.array([1.0, 0.0, 1.0, 1.0], np.float32)
    idx, score = model.approx_select(
        jnp.array(planes), jnp.array(offs), jnp.array(mask), jnp.array(phi), jnp.float32(1.0)
    )
    assert int(idx) == 3
    np.testing.assert_allclose(float(score), 4.0, rtol=1e-6)


def test_approx_select_padding_rows_never_selected():
    # Zero-padded rows (mask 0) with zero offset would otherwise tie; the
    # mask must exclude them even when all live scores are negative.
    planes = np.zeros((4, 8), np.float32)
    offs = np.array([-2.0, -3.0, 0.0, 0.0], np.float32)
    mask = np.array([1.0, 1.0, 0.0, 0.0], np.float32)
    phi = np.zeros(8, np.float32)
    idx, _ = model.approx_select(
        jnp.array(planes), jnp.array(offs), jnp.array(mask), jnp.array(phi), jnp.float32(1.0)
    )
    assert int(idx) == 0


def test_lower_produces_pjrt_safe_hlo():
    text = model.lower_to_hlo_text(
        model.plane_scores, jnp.zeros((16, 64), "float32"), jnp.zeros((64,), "float32")
    )
    assert text.startswith("HloModule")
    assert "custom-call" not in text, "Mosaic custom-call would not run on CPU PJRT"
    text2 = model.lower_to_hlo_text(
        model.matmul_bt, jnp.zeros((16, 16), "float32"), jnp.zeros((8, 16), "float32")
    )
    assert "custom-call" not in text2
